//! `ServingSession`: one job, one device, one policy.
//!
//! One session serves one job on one device under one [`Policy`], either
//! **closed-loop** (batches issued back-to-back — the paper's evaluation
//! setup, `ArrivalPattern::Closed`) or **open-loop**, in which case the
//! session is a thin driver over the shared [`super::engine`] event loop
//! (arrival generation — Poisson/uniform/bursty/trace replay — size- or
//! timeout-triggered batch formation, sojourn-latency charging, bounded
//! queue drop accounting, and optional SLO deadline shedding). `Fleet`
//! drives the very same engine with one [`super::engine::OpenLoop`] per
//! member, so single-job and multi-tenant serving cannot drift apart.
//!
//! Sessions are built with a validating builder:
//!
//! ```ignore
//! let out = ServingSession::builder()
//!     .job(&job)
//!     .device(GpuSim::for_paper_dnn(job.dnn, job.dataset, 7).unwrap())
//!     .policy(PolicySpec::DnnScaler)
//!     .arrivals(ArrivalPattern::poisson(80.0))
//!     .build()?      // typed ConfigError instead of a panic deep in serve
//!     .run()?;       // JobOutcome
//! ```
//!
//! Closed-loop runs reproduce the original (pre-PR 1) serving loop
//! exactly (same device-RNG consumption order, same accounting), so
//! every paper figure/table regenerates unchanged through this API.

use crate::device::{Device, DeviceError};
use crate::gpusim::PartitionError;
use crate::workload::{validate_trace, ArrivalPattern, TraceError};

use super::clipper::Clipper;
use super::controller::Method;
use super::engine::{OpenLoop, SmShare, WindowAccum};
use super::job::JobSpec;
use super::latency::LatencyWindow;
use super::matcomp::LatencyLibrary;
use super::policy::{Action, Policy, QueuePolicy, StaticPolicy, WindowObservation};
use super::profiler::{ProfileOutcome, Profiler};
use super::scaler_batching::BatchScaler;
use super::scaler_mt::MtScaler;
use super::slo::{CombinedPolicy, SloClass};
use super::{MAX_BS, MAX_MTL};

use std::fmt;

/// Engine default for the open-loop batch-formation timeout (ms): a
/// partial batch is dispatched once its oldest request has waited this
/// long. Single source of truth for the builders and the CLI.
pub const DEFAULT_BATCH_TIMEOUT_MS: f64 = 5.0;

/// Serving-loop configuration shared by every session kind.
#[derive(Debug, Clone)]
pub struct RunConfig {
    /// Number of control windows.
    pub windows: usize,
    /// Batch rounds executed per window.
    pub rounds_per_window: usize,
    /// Optional SLO schedule: `(window_index, new_slo_ms)` steps applied
    /// in order (sensitivity analysis, Figs. 9-10).
    pub slo_schedule: Vec<(usize, f64)>,
    /// Batch-size ceiling (128 on the P40; the largest exported artifact
    /// in real mode).
    pub max_bs: u32,
    /// Instance-count ceiling (10 on the P40).
    pub max_mtl: u32,
    /// Profiler probe points (paper: m = 32, n = 8); clamped to the
    /// ceilings above.
    pub probe_bs: u32,
    pub probe_mtl: u32,
}

impl Default for RunConfig {
    fn default() -> Self {
        RunConfig {
            windows: 60,
            rounds_per_window: 20,
            slo_schedule: Vec::new(),
            max_bs: MAX_BS,
            max_mtl: MAX_MTL,
            probe_bs: 32,
            probe_mtl: 8,
        }
    }
}

impl RunConfig {
    /// Config with the paper's knobs but custom window counts.
    pub fn windows(windows: usize, rounds_per_window: usize) -> Self {
        RunConfig { windows, rounds_per_window, ..Default::default() }
    }
}

/// Per-window trace record (the raw material of Figs. 7-10).
#[derive(Debug, Clone)]
pub struct WindowRecord {
    pub window: usize,
    pub bs: u32,
    pub mtl: u32,
    pub slo_ms: f64,
    pub p95_ms: f64,
    pub mean_ms: f64,
    /// Requests completed / window wall time.
    pub throughput: f64,
    /// Window wall time (seconds): closed-loop, the summed batch
    /// latencies (+ pending launch); open-loop, elapsed virtual time.
    pub duration_s: f64,
    pub power_w: f64,
    /// Peak queue depth seen during the window (0 closed-loop).
    pub queue_peak: usize,
    /// Offered arrival rate during the window, requests/s (0 closed-loop).
    pub arrival_rate: f64,
    /// Requests dropped during the window (bounded queue only).
    pub drops: u64,
    /// Requests shed during the window because their queueing delay alone
    /// exceeded the SLO (deadline shedding only).
    pub drops_deadline: u64,
}

/// Result of one serving run.
#[derive(Debug, Clone)]
pub struct JobOutcome {
    pub job_id: u32,
    pub dnn: String,
    pub controller: String,
    /// Method DNNScaler's profiler chose (None for other policies).
    pub method: Option<Method>,
    /// Final operating point.
    pub steady_bs: u32,
    pub steady_mtl: u32,
    /// Mean throughput over the steady half of the run (inferences/s).
    pub throughput: f64,
    /// p95 latency over the steady half (ms). Open-loop sessions report
    /// *sojourn* latency — queueing delay included.
    pub p95_ms: f64,
    /// Fraction of requests whose latency met the SLO in effect (whole
    /// run, including the search/convergence phase).
    pub slo_attainment: f64,
    /// Same, restricted to the steady half of the run — the paper's
    /// Fig. 6 regime, after the knob has converged.
    pub steady_attainment: f64,
    /// Mean power over the steady half (W); 0 in real mode.
    pub power_w: f64,
    /// Per-window trace.
    pub trace: Vec<WindowRecord>,
    /// Per-request (latency, weight) pairs for CDFs (weight = requests
    /// that observed that latency).
    pub latencies: Vec<(f64, f64)>,
    /// Profiler outcome (DNNScaler only).
    pub profile: Option<ProfileOutcome>,
    /// Requests that arrived over the whole run (0 closed-loop — there is
    /// no arrival process).
    pub arrived: u64,
    /// Requests dropped over the whole run (bounded queue only).
    pub drops: u64,
    /// Requests shed over the whole run because their queueing delay
    /// alone exceeded the SLO (deadline shedding only).
    pub dropped_deadline: u64,
    /// Requests lost to device crashes: queued work torn out of the
    /// member's queue at a fault barrier (cluster fault injection only;
    /// always 0 elsewhere).
    pub dropped_failure: u64,
    /// SLO-met throughput over the steady half (inferences/s): the
    /// goodput the paper's attainment claims are really about.
    pub goodput: f64,
    /// Queue high-water mark over the whole run (0 closed-loop).
    pub queue_peak: usize,
    /// Service class this member served under (fleet/cluster
    /// `slo_class` knob only; None everywhere else — and the snapshot
    /// stays byte-identical when None).
    pub slo_class: Option<SloClass>,
}

impl JobOutcome {
    /// Power efficiency (throughput per watt); None when power unknown.
    pub fn power_efficiency(&self) -> Option<f64> {
        (self.power_w > 0.0).then(|| self.throughput / self.power_w)
    }

    /// Mean offered arrival rate over the run (requests/s), weighted by
    /// window duration — idle near-zero-length windows after a finite
    /// trace drains do not dilute it. 0 for closed-loop runs, which have
    /// no arrival process.
    pub fn mean_arrival_rate(&self) -> f64 {
        let total_s: f64 = self.trace.iter().map(|r| r.duration_s).sum();
        if total_s <= 0.0 {
            return 0.0;
        }
        self.trace.iter().map(|r| r.arrival_rate * r.duration_s).sum::<f64>() / total_s
    }
}

/// A session configuration the builder refused to accept.
#[derive(Debug, Clone, PartialEq)]
pub enum ConfigError {
    /// `windows == 0` would leave the steady slice empty.
    ZeroWindows,
    /// `rounds_per_window == 0` would make every window latency-free.
    ZeroRounds,
    /// `max_bs`/`max_mtl` must both be at least 1.
    ZeroKnobCeiling { max_bs: u32, max_mtl: u32 },
    /// No job was supplied to the builder.
    MissingJob,
    /// No device was supplied to the builder.
    MissingDevice,
    /// Open-loop arrival rate must be finite and positive.
    BadArrivalRate { rate: f64 },
    /// Burst shape must satisfy `factor >= 1`, `period_s > 0`,
    /// `0 < burst_s <= period_s`.
    BadBurst { factor: f64, period_s: f64, burst_s: f64 },
    /// A bounded queue must hold at least one request.
    ZeroQueueCapacity,
    /// Batch-formation timeout must be finite and non-negative.
    BadBatchTimeout { timeout_ms: f64 },
    /// A fleet needs at least one member job.
    NoFleetMembers,
    /// A fleet member's DNN has no calibrated simulator profile.
    UnknownDnn { dnn: String },
    /// An `ArrivalPattern::Trace` failed validation (unsorted, negative,
    /// non-finite, or empty timestamps).
    BadTrace(TraceError),
    /// Deadline shedding needs an arrival process (a closed loop has no
    /// queueing delay to shed on).
    ShedRequiresOpenLoop,
    /// An explicit shed deadline must be finite and positive.
    BadDeadline { deadline_ms: f64 },
    /// An explicit `deadline_ms` only acts at shed time: setting it with
    /// shedding disabled would be a silent no-op, so it is refused.
    DeadlineRequiresShed,
    /// A per-member fleet knob (`queue_capacity`, `batch_timeout_ms`,
    /// `shed_deadline`) was set before any member job was added.
    MemberKnobBeforeJob { knob: &'static str },
    /// A queueing knob was set on closed-loop arrivals (closed loops
    /// have no queue, so the knob would be a silent no-op).
    KnobRequiresOpenLoop { knob: &'static str },
    /// A fleet must be entirely closed-loop or entirely open-loop; the
    /// lockstep-window and event-loop schedulers cannot be mixed.
    MixedArrivalModes,
    /// The fleet's spatial partition plan was rejected (over-subscribed
    /// reservations, an invalid fraction, a sub-slice MIG reservation).
    BadPartition(PartitionError),
    /// A partition knob (`sm_reservation`, `partition_policy`) was set on
    /// a `TimeShare` fleet, where there are no partitions to configure.
    KnobRequiresPartition { knob: &'static str },
    /// A list-valued knob (`sm_reservations`, `poisson_rates`) carried
    /// neither one value (broadcast) nor exactly one per member. Longer
    /// lists used to be silently truncated; now they are refused.
    ListCountMismatch { knob: &'static str, got: usize, members: usize },
    /// Both the whole-list form of a knob and its per-member form were
    /// set; applying the list would silently overwrite the per-member
    /// values, so the combination is refused.
    ListOverridesMemberKnob { list: &'static str, knob: &'static str },
    /// A cluster needs at least one device before jobs can be placed.
    NoClusterDevices,
    /// A cluster device spec string (`p40`, `t4`, `p40:mig4`, ...) could
    /// not be parsed.
    BadDeviceSpec { spec: String },
    /// Carving this GPU into that many MIG slices leaves each virtual
    /// device an SM fraction below the model's `MIN_GRANT` floor.
    SliceTooSmall { gpu: String, slices: u32, fraction: f64 },
    /// The cluster's job placement failed or produced an infeasible
    /// assignment (see `coordinator::cluster`).
    Placement(super::cluster::PlacementError),
    /// A churn schedule references a window or job the run cannot honor
    /// (window out of range, retiring an unknown/already-retired job,
    /// launching a closed-loop job, or a launch whose spec is invalid).
    BadChurn { reason: String },
    /// Churn, migration, and autoscaling all run on the window-boundary
    /// event loop, which only exists for open-loop (arrival-driven)
    /// clusters.
    DynamicsRequireOpenLoop,
    /// A fault schedule references a window or device the run cannot
    /// honor, carries invalid degrade parameters, breaks the
    /// crash/repair state machine (double crash, repair of a healthy
    /// device), or has non-positive MTBF/MTTR.
    BadFaults { reason: String },
}

impl fmt::Display for ConfigError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            ConfigError::ZeroWindows => write!(f, "windows must be >= 1 (got 0)"),
            ConfigError::ZeroRounds => write!(f, "rounds_per_window must be >= 1 (got 0)"),
            ConfigError::ZeroKnobCeiling { max_bs, max_mtl } => {
                write!(f, "knob ceilings must be >= 1 (got max_bs={max_bs}, max_mtl={max_mtl})")
            }
            ConfigError::MissingJob => write!(f, "session needs a job (builder .job(..))"),
            ConfigError::MissingDevice => write!(f, "session needs a device (builder .device(..))"),
            ConfigError::BadArrivalRate { rate } => {
                write!(f, "arrival rate must be finite and > 0 (got {rate})")
            }
            ConfigError::BadBurst { factor, period_s, burst_s } => write!(
                f,
                "burst shape invalid (factor={factor}, period_s={period_s}, burst_s={burst_s}): \
                 need factor >= 1, period_s > 0, 0 < burst_s <= period_s"
            ),
            ConfigError::ZeroQueueCapacity => {
                write!(f, "queue capacity must be >= 1 (omit it for an unbounded queue)")
            }
            ConfigError::BadBatchTimeout { timeout_ms } => {
                write!(f, "batch timeout must be finite and >= 0 ms (got {timeout_ms})")
            }
            ConfigError::NoFleetMembers => write!(f, "fleet needs at least one job (.job(..))"),
            ConfigError::UnknownDnn { dnn } => {
                write!(f, "unknown DNN {dnn:?} (no calibrated gpusim profile; see `dnnscaler zoo`)")
            }
            ConfigError::BadTrace(e) => write!(f, "invalid arrival trace: {e}"),
            ConfigError::ShedRequiresOpenLoop => {
                write!(f, "deadline shedding requires open-loop arrivals (closed loops do not queue)")
            }
            ConfigError::BadDeadline { deadline_ms } => {
                write!(f, "deadline_ms must be finite and > 0 (got {deadline_ms})")
            }
            ConfigError::DeadlineRequiresShed => write!(
                f,
                "deadline_ms only acts when deadline shedding is on; \
                 enable shed_deadline on the member or drop the knob"
            ),
            ConfigError::MemberKnobBeforeJob { knob } => {
                write!(f, "{knob} applies to the most recently added fleet member; add a job first")
            }
            ConfigError::KnobRequiresOpenLoop { knob } => {
                write!(
                    f,
                    "{knob} was set but the arrivals are closed-loop (no queue exists); \
                     configure an open arrival pattern or drop the knob"
                )
            }
            ConfigError::MixedArrivalModes => {
                write!(f, "fleet members must be all closed-loop or all open-loop, not a mix")
            }
            ConfigError::BadPartition(e) => write!(f, "invalid SM partition plan: {e}"),
            ConfigError::KnobRequiresPartition { knob } => write!(
                f,
                "{knob} was set but the fleet partition mode is timeshare; \
                 select --partition mps or mig (PartitionMode::Mps/MigSlices) first"
            ),
            ConfigError::ListCountMismatch { knob, got, members } => write!(
                f,
                "{knob} needs 1 value or one per member ({members} member(s), got {got} values)"
            ),
            ConfigError::ListOverridesMemberKnob { list, knob } => write!(
                f,
                "{list} would overwrite per-member {knob} values already set; \
                 use either the whole-list form or the per-member form, not both"
            ),
            ConfigError::NoClusterDevices => {
                write!(f, "cluster needs at least one device (.device(..))")
            }
            ConfigError::BadDeviceSpec { spec } => write!(
                f,
                "cannot parse device spec {spec:?} (expected NAME or NAME:migN, \
                 with NAME one of p40, p4, t4)"
            ),
            ConfigError::SliceTooSmall { gpu, slices, fraction } => write!(
                f,
                "{gpu} split into {slices} MIG slices leaves each virtual device only \
                 {fraction:.4} of the calibration GPU's SMs, below the {MIN_GRANT} \
                 minimum grant; use fewer slices or a bigger card",
                MIN_GRANT = crate::gpusim::MIN_GRANT
            ),
            ConfigError::Placement(e) => write!(f, "job placement failed: {e}"),
            ConfigError::BadChurn { reason } => write!(f, "bad churn schedule: {reason}"),
            ConfigError::DynamicsRequireOpenLoop => write!(
                f,
                "churn/migration/autoscaling require open-loop arrivals on every job"
            ),
            ConfigError::BadFaults { reason } => write!(f, "bad fault schedule: {reason}"),
        }
    }
}

impl std::error::Error for ConfigError {}

/// Which policy a session should serve with. `DnnScaler` runs the paper's
/// Profiler at session start and builds the matching scaler (MT seeded by
/// matrix completion from the profiling latencies).
pub enum PolicySpec<'a> {
    /// Full DNNScaler: profile, pick Batching or Multi-Tenancy, scale.
    DnnScaler,
    /// The Clipper baseline (batching-only AIMD, NSDI'17).
    Clipper,
    /// Queue-aware proactive instance scaling (D-STACK-style demand
    /// estimation): acts on queue depth / arrival rate / drops *before*
    /// p95 crosses the SLO. Intended for open-loop serving.
    QueueAware,
    /// The paper's joint Batching + Multi-Tenancy search
    /// ([`super::slo::CombinedPolicy`]): scores candidate (bs, mtl)
    /// moves against p95-vs-deadline headroom every window and picks
    /// the feasible move maximizing projected goodput.
    Combined,
    /// Static-knob baseline: serve at a fixed point forever.
    Static { bs: u32, mtl: u32 },
    /// Any user-supplied policy.
    Custom(Box<dyn Policy + 'a>),
}

impl<'a> PolicySpec<'a> {
    /// Wrap any policy implementation.
    pub fn custom(policy: impl Policy + 'a) -> Self {
        PolicySpec::Custom(Box::new(policy))
    }
}

impl fmt::Debug for PolicySpec<'_> {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            PolicySpec::DnnScaler => write!(f, "DnnScaler"),
            PolicySpec::Clipper => write!(f, "Clipper"),
            PolicySpec::QueueAware => write!(f, "QueueAware"),
            PolicySpec::Combined => write!(f, "Combined"),
            PolicySpec::Static { bs, mtl } => write!(f, "Static {{ bs: {bs}, mtl: {mtl} }}"),
            PolicySpec::Custom(_) => write!(f, "Custom(..)"),
        }
    }
}

/// Builder for [`ServingSession`]; `build()` validates the configuration
/// and returns a typed [`ConfigError`] instead of panicking mid-serve.
pub struct SessionBuilder<'a> {
    cfg: RunConfig,
    job: Option<JobSpec>,
    device: Option<Box<dyn Device + 'a>>,
    policy: PolicySpec<'a>,
    arrivals: ArrivalPattern,
    queue_capacity: Option<usize>,
    /// None = engine default (5 ms); optional so `build()` can tell
    /// "never set" apart from "set on a closed loop" (an error).
    batch_timeout_ms: Option<f64>,
    shed_deadline: bool,
    seed: u64,
}

impl<'a> SessionBuilder<'a> {
    fn new() -> Self {
        SessionBuilder {
            cfg: RunConfig::default(),
            job: None,
            device: None,
            policy: PolicySpec::DnnScaler,
            arrivals: ArrivalPattern::Closed,
            queue_capacity: None,
            batch_timeout_ms: None,
            shed_deadline: false,
            seed: 42,
        }
    }

    /// Replace the whole serving config (windows, ceilings, SLO schedule).
    pub fn config(mut self, cfg: RunConfig) -> Self {
        self.cfg = cfg;
        self
    }

    /// The job to serve (`JobSpec` is `Copy`; the reference is not held).
    pub fn job(mut self, job: &JobSpec) -> Self {
        self.job = Some(*job);
        self
    }

    /// The device to serve on. Accepts owned devices (`GpuSim`) and
    /// mutable borrows (`&mut dyn Device`) alike.
    pub fn device(mut self, device: impl Device + 'a) -> Self {
        self.device = Some(Box::new(device));
        self
    }

    /// The serving policy (default: [`PolicySpec::DnnScaler`]).
    pub fn policy(mut self, policy: PolicySpec<'a>) -> Self {
        self.policy = policy;
        self
    }

    /// Arrival process (default: [`ArrivalPattern::Closed`], the paper's
    /// closed-loop setup).
    pub fn arrivals(mut self, pattern: ArrivalPattern) -> Self {
        self.arrivals = pattern;
        self
    }

    /// Number of control windows.
    pub fn windows(mut self, windows: usize) -> Self {
        self.cfg.windows = windows;
        self
    }

    /// Batch rounds per control window.
    pub fn rounds_per_window(mut self, rounds: usize) -> Self {
        self.cfg.rounds_per_window = rounds;
        self
    }

    /// Runtime SLO steps `(window_index, new_slo_ms)` (Figs. 9-10).
    pub fn slo_schedule(mut self, steps: Vec<(usize, f64)>) -> Self {
        self.cfg.slo_schedule = steps;
        self
    }

    /// Bound the request queue; overflowing arrivals are dropped and
    /// counted (default: unbounded).
    pub fn queue_capacity(mut self, capacity: usize) -> Self {
        self.queue_capacity = Some(capacity);
        self
    }

    /// Open-loop batch-formation timeout: a partial batch is dispatched
    /// once its oldest request has waited this long (default 5 ms).
    pub fn batch_timeout_ms(mut self, timeout_ms: f64) -> Self {
        self.batch_timeout_ms = Some(timeout_ms);
        self
    }

    /// SLO-aware deadline shedding (open loop only, default off): at
    /// dispatch time, requests whose queueing delay alone already exceeds
    /// the SLO in effect are dropped and counted in
    /// [`JobOutcome::dropped_deadline`] instead of wasting batch slots.
    pub fn shed_deadline(mut self, enabled: bool) -> Self {
        self.shed_deadline = enabled;
        self
    }

    /// Seed for the arrival process (device noise is seeded by the device).
    pub fn seed(mut self, seed: u64) -> Self {
        self.seed = seed;
        self
    }

    /// Validate and assemble the session.
    pub fn build(self) -> Result<ServingSession<'a>, ConfigError> {
        if self.cfg.windows == 0 {
            return Err(ConfigError::ZeroWindows);
        }
        if self.cfg.rounds_per_window == 0 {
            return Err(ConfigError::ZeroRounds);
        }
        if self.cfg.max_bs == 0 || self.cfg.max_mtl == 0 {
            return Err(ConfigError::ZeroKnobCeiling {
                max_bs: self.cfg.max_bs,
                max_mtl: self.cfg.max_mtl,
            });
        }
        validate_pattern(&self.arrivals)?;
        if self.queue_capacity == Some(0) {
            return Err(ConfigError::ZeroQueueCapacity);
        }
        if let Some(t) = self.batch_timeout_ms {
            if !t.is_finite() || t < 0.0 {
                return Err(ConfigError::BadBatchTimeout { timeout_ms: t });
            }
        }
        // Queueing knobs are meaningless closed-loop (there is no queue);
        // refuse to silently discard any of them.
        if self.arrivals.is_closed() {
            if self.shed_deadline {
                return Err(ConfigError::ShedRequiresOpenLoop);
            }
            if self.queue_capacity.is_some() {
                return Err(ConfigError::KnobRequiresOpenLoop { knob: "queue_capacity" });
            }
            if self.batch_timeout_ms.is_some() {
                return Err(ConfigError::KnobRequiresOpenLoop { knob: "batch_timeout_ms" });
            }
        }
        let job = self.job.ok_or(ConfigError::MissingJob)?;
        let device = self.device.ok_or(ConfigError::MissingDevice)?;
        Ok(ServingSession {
            cfg: self.cfg,
            job,
            device,
            policy: self.policy,
            arrivals: self.arrivals,
            queue_capacity: self.queue_capacity,
            batch_timeout_ms: self.batch_timeout_ms.unwrap_or(DEFAULT_BATCH_TIMEOUT_MS),
            shed_deadline: self.shed_deadline,
            seed: self.seed,
        })
    }
}

/// Validate an arrival pattern's shape (shared by `SessionBuilder` and
/// `FleetBuilder`, so hand-built patterns are re-checked everywhere).
pub(crate) fn validate_pattern(pattern: &ArrivalPattern) -> Result<(), ConfigError> {
    match pattern {
        ArrivalPattern::Closed => Ok(()),
        ArrivalPattern::Uniform { rate } | ArrivalPattern::Poisson { rate } => {
            if !rate.is_finite() || *rate <= 0.0 {
                return Err(ConfigError::BadArrivalRate { rate: *rate });
            }
            Ok(())
        }
        ArrivalPattern::Bursty { rate, factor, period_s, burst_s } => {
            if !rate.is_finite() || *rate <= 0.0 {
                return Err(ConfigError::BadArrivalRate { rate: *rate });
            }
            if !factor.is_finite()
                || *factor < 1.0
                || !period_s.is_finite()
                || *period_s <= 0.0
                || !burst_s.is_finite()
                || *burst_s <= 0.0
                || burst_s > period_s
            {
                return Err(ConfigError::BadBurst {
                    factor: *factor,
                    period_s: *period_s,
                    burst_s: *burst_s,
                });
            }
            Ok(())
        }
        ArrivalPattern::Trace(ts) => validate_trace(ts).map_err(ConfigError::BadTrace),
        // Streamed traces were fully validated when the source was opened
        // (`TraceSource::open` rejects unsorted/negative/empty traces).
        ArrivalPattern::Streamed(_) => Ok(()),
    }
}

/// A validated serving session, ready to run.
pub struct ServingSession<'a> {
    cfg: RunConfig,
    job: JobSpec,
    device: Box<dyn Device + 'a>,
    policy: PolicySpec<'a>,
    arrivals: ArrivalPattern,
    queue_capacity: Option<usize>,
    batch_timeout_ms: f64,
    shed_deadline: bool,
    seed: u64,
}

impl<'a> ServingSession<'a> {
    pub fn builder() -> SessionBuilder<'a> {
        SessionBuilder::new()
    }

    /// Serve the configured job to completion.
    pub fn run(self) -> Result<JobOutcome, DeviceError> {
        let ServingSession {
            cfg,
            job,
            mut device,
            policy: spec,
            arrivals,
            queue_capacity,
            batch_timeout_ms,
            shed_deadline,
            seed,
        } = self;
        let (mut policy, profile, label) = resolve_policy(spec, &cfg, &job, device.as_mut())?;
        let mut out = match arrivals {
            ArrivalPattern::Closed => run_closed(&cfg, &job, device.as_mut(), policy.as_mut())?,
            pattern => {
                // Profiling happened in virtual time too: arrivals that
                // landed during it start the serve with a backlog.
                let overhead_ms = profile.as_ref().map_or(0.0, |p| p.overhead_ms);
                run_open(
                    &cfg,
                    &job,
                    device.as_mut(),
                    policy.as_mut(),
                    OpenLoop::new(
                        pattern,
                        seed,
                        queue_capacity,
                        batch_timeout_ms,
                        shed_deadline,
                        overhead_ms / 1000.0,
                    ),
                )?
            }
        };
        if let Some(name) = label {
            out.controller = name.to_string();
        }
        out.method = profile.as_ref().map(|p| p.method);
        out.profile = profile;
        Ok(out)
    }
}

/// Resolve a [`PolicySpec`] into a live policy, running the Profiler for
/// `DnnScaler` (shared with `Fleet`).
pub(crate) fn resolve_policy<'a>(
    spec: PolicySpec<'a>,
    cfg: &RunConfig,
    job: &JobSpec,
    device: &mut dyn Device,
) -> Result<(Box<dyn Policy + 'a>, Option<ProfileOutcome>, Option<&'static str>), DeviceError> {
    Ok(match spec {
        PolicySpec::DnnScaler => {
            let profiler = Profiler {
                probe_bs: cfg.probe_bs.min(cfg.max_bs),
                probe_mtl: cfg.probe_mtl.min(cfg.max_mtl),
                batches_per_point: 5,
            };
            let profile = profiler.run(device)?;
            let policy: Box<dyn Policy + 'a> = match profile.method {
                Method::Batching => Box::new(BatchScaler::with_limits(1, cfg.max_bs)),
                Method::MultiTenancy => {
                    let lib = LatencyLibrary::from_paper_profiles(job.dnn, cfg.max_mtl);
                    // The two MT observations come free from profiling.
                    let observed =
                        [(1u32, profile.lat_base_ms), (profiler.probe_mtl, profile.lat_mt_ms)];
                    Box::new(MtScaler::seeded(&lib, &observed, job.slo_ms))
                }
            };
            (policy, Some(profile), Some("dnnscaler"))
        }
        PolicySpec::Clipper => (Box::new(Clipper::with_params(4, 0.10, cfg.max_bs)), None, None),
        PolicySpec::QueueAware => (Box::new(QueuePolicy::new(cfg.max_mtl)), None, None),
        PolicySpec::Combined => {
            (Box::new(CombinedPolicy::new(cfg.max_bs, cfg.max_mtl)), None, None)
        }
        PolicySpec::Static { bs, mtl } => (
            Box::new(StaticPolicy::new(bs.clamp(1, cfg.max_bs), mtl.clamp(1, cfg.max_mtl))),
            None,
            None,
        ),
        PolicySpec::Custom(policy) => (policy, None, None),
    })
}

/// Applies `(window_index, slo_ms)` steps in order as windows advance.
pub(crate) struct SloSchedule {
    steps: std::iter::Peekable<std::vec::IntoIter<(usize, f64)>>,
    current: f64,
}

impl SloSchedule {
    pub(crate) fn new(initial: f64, mut steps: Vec<(usize, f64)>) -> Self {
        steps.sort_by_key(|(w, _)| *w);
        SloSchedule { steps: steps.into_iter().peekable(), current: initial }
    }

    /// SLO in effect at window `w` (consumes due steps).
    pub(crate) fn at(&mut self, w: usize) -> f64 {
        while let Some(&(at, slo)) = self.steps.peek() {
            if at <= w {
                self.current = slo;
                self.steps.next();
            } else {
                break;
            }
        }
        self.current
    }
}

/// Online SLO-attainment accumulator (whole run + steady half).
pub(crate) struct AttainAcc {
    steady_from: usize,
    met: f64,
    total: f64,
    steady_met: f64,
    steady_total: f64,
}

impl AttainAcc {
    pub(crate) fn new(steady_from: usize) -> Self {
        AttainAcc { steady_from, met: 0.0, total: 0.0, steady_met: 0.0, steady_total: 0.0 }
    }

    /// Absorb one window's per-request latencies against its SLO (open
    /// loop: every request counts with weight 1).
    pub(crate) fn absorb(&mut self, window: usize, slo_ms: f64, latencies: &[f64]) {
        for &lat in latencies {
            self.absorb_one(window, slo_ms, lat, 1.0);
        }
    }

    /// Absorb one window's `(latency, weight)` pairs against its SLO
    /// (closed loop: one batch record weighted by its request count).
    pub(crate) fn absorb_weighted(&mut self, window: usize, slo_ms: f64, latencies: &[(f64, f64)]) {
        for &(lat, weight) in latencies {
            self.absorb_one(window, slo_ms, lat, weight);
        }
    }

    #[inline]
    fn absorb_one(&mut self, window: usize, slo_ms: f64, lat: f64, weight: f64) {
        let ok = lat <= slo_ms;
        if ok {
            self.met += weight;
        }
        self.total += weight;
        if window >= self.steady_from {
            if ok {
                self.steady_met += weight;
            }
            self.steady_total += weight;
        }
    }

    /// Whole-run attainment; 0 (not NaN) when no requests were served.
    pub(crate) fn attainment(&self) -> f64 {
        if self.total == 0.0 {
            0.0
        } else {
            self.met / self.total
        }
    }

    pub(crate) fn steady_attainment(&self) -> f64 {
        self.steady_met / self.steady_total.max(1e-12)
    }
}

/// Fold a finished trace into a [`JobOutcome`] (steady half statistics).
#[allow(clippy::too_many_arguments)]
pub(crate) fn assemble_outcome(
    job: &JobSpec,
    controller: String,
    steady_point: (u32, u32),
    trace: Vec<WindowRecord>,
    latencies: Vec<(f64, f64)>,
    acc: &AttainAcc,
    arrived: u64,
    drops: u64,
    dropped_deadline: u64,
    queue_peak: usize,
) -> JobOutcome {
    // Steady-state = last half of the run. An empty trace is legal under
    // fault injection (a job stranded by a crash before it ever served a
    // window) and folds to all-zero statistics, not NaN.
    let steady = &trace[trace.len() / 2..];
    let (throughput, power_w, p95_ms) = if steady.is_empty() {
        (0.0, 0.0, 0.0)
    } else {
        let throughput = steady.iter().map(|r| r.throughput).sum::<f64>() / steady.len() as f64;
        let power_w = steady.iter().map(|r| r.power_w).sum::<f64>() / steady.len() as f64;
        let mut steady_lat: Vec<f64> = steady.iter().map(|r| r.p95_ms).collect();
        // total_cmp: a NaN window percentile (possible only if a device
        // returned NaN latencies) must not panic the final fold.
        steady_lat.sort_by(|a, b| a.total_cmp(b));
        let p95 = steady_lat
            [((steady_lat.len() as f64 * 0.95).ceil() as usize - 1).min(steady_lat.len() - 1)];
        (throughput, power_w, p95)
    };
    let steady_attainment = acc.steady_attainment();

    JobOutcome {
        job_id: job.id,
        dnn: job.dnn.to_string(),
        controller,
        method: None,
        steady_bs: steady_point.0,
        steady_mtl: steady_point.1,
        throughput,
        p95_ms,
        slo_attainment: acc.attainment(),
        steady_attainment,
        power_w,
        trace,
        latencies,
        profile: None,
        arrived,
        drops,
        dropped_deadline,
        dropped_failure: 0,
        goodput: throughput * steady_attainment,
        queue_peak,
        slo_class: None,
    }
}

/// Serve one closed-loop control window at `(bs, mtl)` and fold it into
/// the shared accumulators. `share` sets the SM regime: time-sharing
/// (`SmShare::Inflate` — every observed batch latency scaled by the
/// fleet's contention factor, 1.0 solo) or a spatial partition
/// (`SmShare::Grant` — executed inside the member's SM grant, no
/// inflation). `pending_launch_ms` is charged into this window's wall
/// time. Shared by [`run_closed`] and `Fleet` so the window accounting
/// cannot drift between the two.
#[allow(clippy::too_many_arguments)]
pub(crate) fn serve_closed_window(
    cfg: &RunConfig,
    w: usize,
    slo: f64,
    (bs, mtl): (u32, u32),
    share: SmShare,
    pending_launch_ms: f64,
    device: &mut dyn Device,
    window: &mut LatencyWindow,
    latencies: &mut Vec<(f64, f64)>,
    acc: &mut AttainAcc,
) -> Result<(WindowRecord, WindowObservation), DeviceError> {
    let mut wall_ms = pending_launch_ms;
    let mut requests = 0.0;
    let mut power_acc = 0.0;
    let mut sm_acc = 0.0;
    window.reset();
    let mut win_lat: Vec<(f64, f64)> = Vec::with_capacity(cfg.rounds_per_window);

    for _ in 0..cfg.rounds_per_window {
        let (s, lat_ms) = match share {
            SmShare::Inflate(factor) => {
                let s = device.execute_batch(bs, mtl)?;
                (s, s.latency_ms * factor)
            }
            SmShare::Grant(grant) => {
                let s = device.execute_batch_granted(bs, mtl, grant)?;
                (s, s.latency_ms)
            }
            SmShare::GrantInflate { grant, factor } => {
                let s = device.execute_batch_granted(bs, mtl, grant)?;
                (s, s.latency_ms * factor)
            }
        };
        window.record(lat_ms);
        wall_ms += lat_ms;
        let reqs = (bs * mtl) as f64;
        requests += reqs;
        latencies.push((lat_ms, reqs));
        win_lat.push((lat_ms, reqs));
        power_acc += s.power_w;
        sm_acc += s.sm_util;
    }

    let p95 = window.p95().unwrap_or(0.0);
    let mean = window.mean().unwrap_or(0.0);
    let throughput = requests / (wall_ms / 1000.0);
    let power_w = power_acc / cfg.rounds_per_window as f64;
    acc.absorb_weighted(w, slo, &win_lat);
    let record = WindowRecord {
        window: w,
        bs,
        mtl,
        slo_ms: slo,
        p95_ms: p95,
        mean_ms: mean,
        throughput,
        duration_s: wall_ms / 1000.0,
        power_w,
        queue_peak: 0,
        arrival_rate: 0.0,
        drops: 0,
        drops_deadline: 0,
    };
    let obs = WindowObservation {
        window: w,
        slo_ms: slo,
        p95_ms: p95,
        mean_ms: mean,
        throughput,
        power_w,
        sm_util: sm_acc / cfg.rounds_per_window as f64,
        queue_depth: 0,
        arrival_rate: 0.0,
        drops: 0,
        drops_deadline: 0,
    };
    Ok((record, obs))
}

/// Closed-loop serve: a byte-faithful port of the original closed-loop
/// runner, so figures/tables regenerate identically through this API.
fn run_closed(
    cfg: &RunConfig,
    job: &JobSpec,
    device: &mut dyn Device,
    policy: &mut dyn Policy,
) -> Result<JobOutcome, DeviceError> {
    let mut schedule = SloSchedule::new(job.slo_ms, cfg.slo_schedule.clone());
    let mut window = LatencyWindow::new(cfg.rounds_per_window);
    let mut trace = Vec::with_capacity(cfg.windows);
    let mut latencies: Vec<(f64, f64)> = Vec::new();
    let mut acc = AttainAcc::new(cfg.windows / 2);
    let mut pending_launch_ms = 0.0;

    for w in 0..cfg.windows {
        let slo = schedule.at(w);
        let (bs, mtl) = policy.operating_point();
        let (record, obs) = serve_closed_window(
            cfg,
            w,
            slo,
            (bs, mtl),
            SmShare::Inflate(1.0),
            pending_launch_ms,
            device,
            &mut window,
            &mut latencies,
            &mut acc,
        )?;
        pending_launch_ms = 0.0;
        trace.push(record);
        if let Action::SetPoint { mtl: new_mtl, .. } = policy.observe(&obs) {
            if new_mtl > mtl {
                // Charge instance-launch overhead to the next window.
                pending_launch_ms += device.launch_overhead_ms() * (new_mtl - mtl) as f64;
            }
        }
    }

    Ok(assemble_outcome(
        job,
        policy.name().to_string(),
        policy.operating_point(),
        trace,
        latencies,
        &acc,
        0,
        0,
        0,
        0,
    ))
}

/// Open-loop serve: a thin window driver over the shared
/// [`super::engine`] event loop. Each round [`OpenLoop::serve_round`]
/// forms one batch (size- or timeout-triggered), executes it, charges
/// full sojourn latencies, and advances the virtual clock; this function
/// only sequences windows, applies the SLO schedule, and feeds each
/// window's observation to the policy. `Fleet` drives the same engine
/// with one `OpenLoop` per member, interleaved by next-event time.
fn run_open(
    cfg: &RunConfig,
    job: &JobSpec,
    device: &mut dyn Device,
    policy: &mut dyn Policy,
    mut lp: OpenLoop,
) -> Result<JobOutcome, DeviceError> {
    let mut schedule = SloSchedule::new(job.slo_ms, cfg.slo_schedule.clone());
    let mut trace = Vec::with_capacity(cfg.windows);
    let mut latencies: Vec<(f64, f64)> = Vec::new();
    let mut acc = AttainAcc::new(cfg.windows / 2);
    // One recycled accumulator for the whole run: the latency buffer and
    // percentile scratch inside it are cleared, never reallocated, at
    // each window boundary (the engine's zero-allocation discipline).
    let mut win = WindowAccum::new();

    for w in 0..cfg.windows {
        let slo = schedule.at(w);
        let (bs, mtl) = policy.operating_point();
        win.begin(&lp);
        for _ in 0..cfg.rounds_per_window {
            if !lp.serve_round((bs, mtl), slo, SmShare::Inflate(1.0), device, &mut win)? {
                // Finite trace exhausted and drained: remaining rounds
                // (and windows) have nothing left to serve.
                break;
            }
        }
        let (record, obs) = win.finish(w, slo, (bs, mtl), &lp);
        acc.absorb(w, slo, win.latencies());
        latencies.extend(win.latencies().iter().map(|&l| (l, 1.0)));
        trace.push(record);
        // Unlike the closed loop, instance launches are not charged as a
        // serving stall here: co-located instances are independent
        // processes, so the existing ones keep draining the queue while a
        // new one spins up in the background — it simply only becomes
        // effective at the next window's operating point. (The paper's
        // launch-overhead argument — minimize launch *count* via matrix
        // completion — is still exercised by the closed-loop accounting.)
        policy.observe(&obs);
    }

    Ok(assemble_outcome(
        job,
        policy.name().to_string(),
        policy.operating_point(),
        trace,
        latencies,
        &acc,
        lp.arrived(),
        lp.dropped(),
        lp.dropped_deadline(),
        lp.max_depth(),
    ))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::job::paper_job;
    use crate::gpusim::GpuSim;

    fn sim(job: &JobSpec, seed: u64) -> GpuSim {
        GpuSim::for_paper_dnn(job.dnn, job.dataset, seed).unwrap()
    }

    /// Seeded closed-loop DNNScaler-vs-Clipper pair (ported from the
    /// deleted `JobRunner` shim's tests: same seeds, same expectations —
    /// these pin the paper-calibrated serving behaviour itself).
    fn run_pair(job_id: u32, windows: usize) -> (JobOutcome, JobOutcome) {
        let job = paper_job(job_id).unwrap();
        let cfg = RunConfig::windows(windows, 20);
        let run = |spec: PolicySpec<'static>, seed: u64| {
            ServingSession::builder()
                .config(cfg.clone())
                .job(job)
                .device(sim(job, seed))
                .policy(spec)
                .build()
                .unwrap()
                .run()
                .unwrap()
        };
        let scaler = run(PolicySpec::DnnScaler, 1000 + job_id as u64);
        let clipper = run(PolicySpec::Clipper, 2000 + job_id as u64);
        (scaler, clipper)
    }

    #[test]
    fn builder_rejects_zero_windows_and_rounds() {
        let job = paper_job(1).unwrap();
        for (windows, rounds, want) in [
            (0usize, 20usize, ConfigError::ZeroWindows),
            (10, 0, ConfigError::ZeroRounds),
        ] {
            let err = ServingSession::builder()
                .config(RunConfig { windows, rounds_per_window: rounds, ..Default::default() })
                .job(job)
                .device(sim(job, 1))
                .build()
                .err()
                .expect("must be rejected");
            assert_eq!(err, want);
        }
    }

    #[test]
    fn builder_rejects_missing_parts_and_bad_patterns() {
        let job = paper_job(1).unwrap();
        assert_eq!(
            ServingSession::builder().device(sim(job, 1)).build().err(),
            Some(ConfigError::MissingJob)
        );
        assert_eq!(
            ServingSession::builder().job(job).build().err(),
            Some(ConfigError::MissingDevice)
        );
        assert_eq!(
            ServingSession::builder()
                .job(job)
                .device(sim(job, 1))
                .arrivals(ArrivalPattern::poisson(0.0))
                .build()
                .err(),
            Some(ConfigError::BadArrivalRate { rate: 0.0 })
        );
        assert_eq!(
            ServingSession::builder()
                .job(job)
                .device(sim(job, 1))
                .arrivals(ArrivalPattern::bursty(10.0, 0.5, 4.0, 1.0))
                .build()
                .err(),
            Some(ConfigError::BadBurst { factor: 0.5, period_s: 4.0, burst_s: 1.0 })
        );
        assert_eq!(
            ServingSession::builder().job(job).device(sim(job, 1)).queue_capacity(0).build().err(),
            Some(ConfigError::ZeroQueueCapacity)
        );
        assert_eq!(
            ServingSession::builder()
                .job(job)
                .device(sim(job, 1))
                .batch_timeout_ms(f64::NAN)
                .build()
                .err()
                .map(|e| matches!(e, ConfigError::BadBatchTimeout { .. })),
            Some(true)
        );
    }

    #[test]
    fn job1_mt_beats_clipper() {
        // Job 1 (inc-v1): the paper reports MT with ~7x throughput.
        let (scaler, clipper) = run_pair(1, 40);
        assert_eq!(scaler.method, Some(crate::coordinator::Method::MultiTenancy));
        assert!(scaler.steady_mtl >= 6, "steady mtl {}", scaler.steady_mtl);
        assert!(
            scaler.throughput > 1.5 * clipper.throughput,
            "DNNScaler {:.0}/s must beat Clipper {:.0}/s",
            scaler.throughput,
            clipper.throughput
        );
        assert!(scaler.slo_attainment > 0.9, "attainment {}", scaler.slo_attainment);
        // Clipper's +4 step massively overshoots job 1's knee (BS ~ 4),
        // so its sawtooth spends most windows in violation. The paper
        // shows the same collapse: Table 6 reports Clipper at 32.9 inf/s
        // on job 1 versus 118.7 inf/s base throughput.
        assert!(clipper.slo_attainment > 0.1, "attainment {}", clipper.slo_attainment);
        assert!(clipper.slo_attainment < scaler.slo_attainment);
    }

    #[test]
    fn job3_batching_parity_with_clipper() {
        // Job 3 (inc-v4): both use batching; throughput parity (±20%).
        let (scaler, clipper) = run_pair(3, 40);
        assert_eq!(scaler.method, Some(crate::coordinator::Method::Batching));
        let ratio = scaler.throughput / clipper.throughput;
        assert!((0.8..2.0).contains(&ratio), "ratio {ratio}");
    }

    #[test]
    fn steady_knob_close_to_paper_for_batching_jobs() {
        // Jobs 3 and 12 (inc-v4, resv2-152 on ImageNet): the paper's two
        // canonical batching jobs. Job 17's Caltech knee is dominated by
        // prep calibration we only bound loosely, so it is not asserted.
        use crate::coordinator::job::SteadyKnob;
        for id in [3u32, 12] {
            let job = paper_job(id).unwrap();
            let (scaler, _) = run_pair(id, 40);
            if let SteadyKnob::Bs(paper_bs) = job.paper_steady {
                let got = scaler.steady_bs;
                // Within a factor of ~3 of the paper's steady BS — the
                // absolute knee depends on absolute latency calibration,
                // which we only bound to coarse bands (DESIGN.md §7).
                assert!(
                    got as f64 >= paper_bs as f64 / 3.0 && got as f64 <= paper_bs as f64 * 3.0,
                    "job {id}: steady bs {got} vs paper {paper_bs}"
                );
            }
        }
    }

    #[test]
    fn closed_loop_slo_schedule_sheds_instances() {
        let job = paper_job(1).unwrap();
        let out = ServingSession::builder()
            .config(RunConfig {
                windows: 30,
                rounds_per_window: 10,
                slo_schedule: vec![(15, 10.0)],
                ..Default::default()
            })
            .job(job)
            .device(sim(job, 5))
            .policy(PolicySpec::DnnScaler)
            .build()
            .unwrap()
            .run()
            .unwrap();
        assert_eq!(out.trace[14].slo_ms, 35.0);
        assert_eq!(out.trace[15].slo_ms, 10.0);
        // MT must shed instances when the SLO halves (Fig. 10(a)).
        let before = out.trace[14].mtl;
        let after = out.trace.last().unwrap().mtl;
        assert!(after < before, "mtl {before} -> {after} must shrink");
    }

    #[test]
    fn outcome_accounting_consistent() {
        let (scaler, _) = run_pair(26, 30);
        assert_eq!(scaler.trace.len(), 30);
        assert!(scaler.throughput > 0.0);
        assert!(scaler.p95_ms > 0.0);
        assert!((0.0..=1.0).contains(&scaler.slo_attainment));
        let total_reqs: f64 = scaler.latencies.iter().map(|(_, w)| w).sum();
        assert!(total_reqs > 0.0);
    }

    #[test]
    fn static_policy_serves_at_fixed_point() {
        let job = paper_job(3).unwrap();
        let out = ServingSession::builder()
            .config(RunConfig::windows(6, 5))
            .job(job)
            .device(sim(job, 3))
            .policy(PolicySpec::Static { bs: 8, mtl: 2 })
            .build()
            .unwrap()
            .run()
            .unwrap();
        assert_eq!(out.controller, "static");
        assert_eq!((out.steady_bs, out.steady_mtl), (8, 2));
        assert!(out.trace.iter().all(|r| r.bs == 8 && r.mtl == 2));
        assert!(out.throughput > 0.0);
        assert_eq!(out.method, None);
    }

    #[test]
    fn open_loop_serves_all_offered_load_when_underutilized() {
        // Poisson load far below capacity: every request is served, none
        // dropped, and sojourn latency stays close to service latency.
        let job = paper_job(1).unwrap();
        let out = ServingSession::builder()
            .config(RunConfig::windows(10, 10))
            .job(job)
            .device(sim(job, 21))
            .policy(PolicySpec::Static { bs: 1, mtl: 4 })
            .arrivals(ArrivalPattern::poisson(40.0))
            .batch_timeout_ms(5.0)
            .seed(21)
            .build()
            .unwrap()
            .run()
            .unwrap();
        assert_eq!(out.drops, 0);
        assert!(out.queue_peak >= 1);
        let served: f64 = out.latencies.iter().map(|(_, w)| w).sum();
        assert!(served >= 100.0, "served {served}");
        assert!(out.p95_ms > 0.0);
        // Sojourn >= service: queueing delay can only add latency.
        let svc = sim(job, 0).mean_batch_latency_ms(1, 4);
        assert!(out.p95_ms > svc * 0.9, "p95 {} vs service {svc}", out.p95_ms);
        // Virtual time moved at roughly the offered rate: mean window
        // throughput tracks the arrival rate, not device capacity.
        assert!(out.throughput < 90.0, "open loop must be arrival-bound, got {}", out.throughput);
        assert!(out.throughput > 15.0, "throughput collapsed: {}", out.throughput);
    }

    #[test]
    fn bounded_queue_drops_under_overload() {
        // Offered load far beyond a tiny queue + slow static point: the
        // session must drop and count rather than queue unboundedly.
        let job = paper_job(3).unwrap(); // inc-v4: slow per-batch
        let out = ServingSession::builder()
            .config(RunConfig::windows(6, 8))
            .job(job)
            .device(sim(job, 5))
            .policy(PolicySpec::Static { bs: 1, mtl: 1 })
            .arrivals(ArrivalPattern::poisson(500.0))
            .queue_capacity(16)
            .batch_timeout_ms(2.0)
            .seed(5)
            .build()
            .unwrap()
            .run()
            .unwrap();
        assert!(out.drops > 0, "drops {}", out.drops);
        assert!(out.queue_peak <= 16);
        assert!(out.trace.iter().any(|r| r.drops > 0));
        assert!(out.trace.iter().all(|r| r.queue_peak <= 16));
    }

    #[test]
    fn open_loop_slo_schedule_still_applies() {
        let job = paper_job(1).unwrap();
        let out = ServingSession::builder()
            .config(RunConfig {
                windows: 8,
                rounds_per_window: 6,
                slo_schedule: vec![(4, 10.0)],
                ..Default::default()
            })
            .job(job)
            .device(sim(job, 2))
            .policy(PolicySpec::Clipper)
            .arrivals(ArrivalPattern::poisson(60.0))
            .seed(2)
            .build()
            .unwrap()
            .run()
            .unwrap();
        assert_eq!(out.trace[3].slo_ms, 35.0);
        assert_eq!(out.trace[4].slo_ms, 10.0);
    }

    #[test]
    fn config_error_messages_name_the_field() {
        assert!(ConfigError::ZeroWindows.to_string().contains("windows"));
        assert!(ConfigError::ZeroRounds.to_string().contains("rounds_per_window"));
        assert!(ConfigError::BadArrivalRate { rate: -1.0 }.to_string().contains("-1"));
        assert!(ConfigError::UnknownDnn { dnn: "vgg16".into() }.to_string().contains("vgg16"));
        assert!(ConfigError::ShedRequiresOpenLoop.to_string().contains("open-loop"));
        assert!(ConfigError::MixedArrivalModes.to_string().contains("mix"));
        assert!(ConfigError::BadDeadline { deadline_ms: -3.0 }.to_string().contains("-3"));
        assert!(ConfigError::DeadlineRequiresShed.to_string().contains("shed_deadline"));
    }

    #[test]
    fn builder_rejects_shed_on_closed_loop_and_bad_traces() {
        let job = paper_job(1).unwrap();
        assert_eq!(
            ServingSession::builder()
                .job(job)
                .device(sim(job, 1))
                .shed_deadline(true)
                .build()
                .err(),
            Some(ConfigError::ShedRequiresOpenLoop)
        );
        // Queueing knobs on closed-loop arrivals are rejected, not
        // silently ignored (there is no queue for them to act on).
        assert_eq!(
            ServingSession::builder().job(job).device(sim(job, 1)).queue_capacity(8).build().err(),
            Some(ConfigError::KnobRequiresOpenLoop { knob: "queue_capacity" })
        );
        assert_eq!(
            ServingSession::builder()
                .job(job)
                .device(sim(job, 1))
                .batch_timeout_ms(2.0)
                .build()
                .err(),
            Some(ConfigError::KnobRequiresOpenLoop { knob: "batch_timeout_ms" })
        );
        // A hand-built (unvalidated) Trace variant is re-checked at build.
        let err = ServingSession::builder()
            .job(job)
            .device(sim(job, 1))
            .arrivals(ArrivalPattern::Trace(vec![3.0, 1.0]))
            .build()
            .err();
        assert!(matches!(err, Some(ConfigError::BadTrace(_))), "{err:?}");
        // A validated trace with shedding builds fine.
        assert!(ServingSession::builder()
            .job(job)
            .device(sim(job, 1))
            .arrivals(ArrivalPattern::trace(vec![0.0, 0.5]).unwrap())
            .shed_deadline(true)
            .build()
            .is_ok());
    }
}
