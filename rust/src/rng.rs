//! Deterministic random-number substrate (no external crates).
//!
//! A SplitMix64-seeded xoshiro256++ generator with the distribution
//! samplers the simulator and workload generators need: uniform,
//! exponential (Poisson inter-arrivals), and standard normal / lognormal
//! (latency jitter) via Box-Muller.

/// xoshiro256++ PRNG.
#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
    /// Cached second Box-Muller variate.
    spare_normal: Option<f64>,
}

#[inline]
fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

impl Rng {
    /// Seeded generator; distinct seeds give independent streams.
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        Rng {
            s: [splitmix64(&mut sm), splitmix64(&mut sm), splitmix64(&mut sm), splitmix64(&mut sm)],
            spare_normal: None,
        }
    }

    /// Next raw u64.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[0]
            .wrapping_add(self.s[3])
            .rotate_left(23)
            .wrapping_add(self.s[0]);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform f64 in [0, 1).
    #[inline]
    pub fn uniform(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f32 in [-1, 1).
    #[inline]
    pub fn signed_f32(&mut self) -> f32 {
        ((self.next_u64() >> 41) as f32 / (1u64 << 23) as f32) * 2.0 - 1.0
    }

    /// Uniform f64 in [lo, hi).
    #[inline]
    pub fn uniform_range(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.uniform()
    }

    /// Uniform usize in [0, n).
    #[inline]
    pub fn below(&mut self, n: usize) -> usize {
        (self.next_u64() % n.max(1) as u64) as usize
    }

    /// Bernoulli trial.
    #[inline]
    pub fn chance(&mut self, p: f64) -> bool {
        self.uniform() < p
    }

    /// Exponential variate with the given rate (mean 1/rate).
    #[inline]
    pub fn exponential(&mut self, rate: f64) -> f64 {
        let u = 1.0 - self.uniform(); // (0, 1]
        -u.ln() / rate
    }

    /// Standard normal via Box-Muller (cached pair).
    pub fn normal(&mut self) -> f64 {
        if let Some(z) = self.spare_normal.take() {
            return z;
        }
        let u1 = 1.0 - self.uniform(); // (0, 1]
        let u2 = self.uniform();
        let r = (-2.0 * u1.ln()).sqrt();
        let theta = 2.0 * std::f64::consts::PI * u2;
        self.spare_normal = Some(r * theta.sin());
        r * theta.cos()
    }

    /// Lognormal variate with parameters (mu, sigma) of the underlying
    /// normal.
    #[inline]
    pub fn lognormal(&mut self, mu: f64, sigma: f64) -> f64 {
        (mu + sigma * self.normal()).exp()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_per_seed() {
        let mut a = Rng::new(7);
        let mut b = Rng::new(7);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
        let mut c = Rng::new(8);
        assert_ne!(Rng::new(7).next_u64(), c.next_u64());
    }

    #[test]
    fn uniform_moments() {
        let mut r = Rng::new(1);
        let n = 100_000;
        let samples: Vec<f64> = (0..n).map(|_| r.uniform()).collect();
        let mean = samples.iter().sum::<f64>() / n as f64;
        let var = samples.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.005, "mean {mean}");
        assert!((var - 1.0 / 12.0).abs() < 0.005, "var {var}");
        assert!(samples.iter().all(|&x| (0.0..1.0).contains(&x)));
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(2);
        let n = 100_000;
        let samples: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = samples.iter().sum::<f64>() / n as f64;
        let var = samples.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.03, "var {var}");
    }

    #[test]
    fn exponential_mean() {
        let mut r = Rng::new(3);
        let n = 50_000;
        let mean = (0..n).map(|_| r.exponential(4.0)).sum::<f64>() / n as f64;
        assert!((mean - 0.25).abs() < 0.01, "mean {mean}");
    }

    #[test]
    fn lognormal_median() {
        let mut r = Rng::new(4);
        let mut samples: Vec<f64> = (0..50_000).map(|_| r.lognormal(0.0, 0.5)).collect();
        samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let median = samples[samples.len() / 2];
        assert!((median - 1.0).abs() < 0.03, "median {median}"); // e^mu = 1
        assert!(samples.iter().all(|&x| x > 0.0));
    }

    #[test]
    fn chance_rate() {
        let mut r = Rng::new(5);
        let hits = (0..100_000).filter(|_| r.chance(0.2)).count();
        assert!((19_000..21_000).contains(&hits), "hits {hits}");
    }
}
