//! Synthetic input tensors for the real-execution path.
//!
//! The paper feeds datasets (ImageNet/Caltech/...) whose *contents* don't
//! affect serving behaviour — only their shapes and prep costs do (which
//! the workload module models). For real PJRT execution we synthesize
//! deterministic pseudo-random tensors so runs are reproducible without
//! shipping datasets.

/// Deterministic xorshift-based tensor filler in [-1, 1).
pub struct InputSynth {
    state: u64,
}

impl InputSynth {
    pub fn new(seed: u64) -> Self {
        InputSynth { state: seed.max(1) }
    }

    #[inline]
    fn next_u64(&mut self) -> u64 {
        // xorshift64*
        let mut x = self.state;
        x ^= x >> 12;
        x ^= x << 25;
        x ^= x >> 27;
        self.state = x;
        x.wrapping_mul(0x2545F4914F6CDD1D)
    }

    /// Next f32 in [-1, 1).
    #[inline]
    pub fn next_f32(&mut self) -> f32 {
        // Top 23 bits -> [0, 1) at f32 precision -> [-1, 1).
        ((self.next_u64() >> 41) as f32 / (1u64 << 23) as f32) * 2.0 - 1.0
    }

    /// Fill a buffer of `n` elements.
    pub fn tensor(&mut self, n: usize) -> Vec<f32> {
        (0..n).map(|_| self.next_f32()).collect()
    }

    /// Fill an existing buffer in place (hot-path friendly, no alloc).
    pub fn fill(&mut self, buf: &mut [f32]) {
        for v in buf.iter_mut() {
            *v = self.next_f32();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_and_in_range() {
        let mut a = InputSynth::new(42);
        let mut b = InputSynth::new(42);
        let ta = a.tensor(1000);
        let tb = b.tensor(1000);
        assert_eq!(ta, tb);
        assert!(ta.iter().all(|v| (-1.0..1.0).contains(v)));
    }

    #[test]
    fn different_seeds_differ() {
        let ta = InputSynth::new(1).tensor(100);
        let tb = InputSynth::new(2).tensor(100);
        assert_ne!(ta, tb);
    }

    #[test]
    fn fill_matches_tensor() {
        let mut a = InputSynth::new(7);
        let mut b = InputSynth::new(7);
        let t = a.tensor(64);
        let mut buf = vec![0.0; 64];
        b.fill(&mut buf);
        assert_eq!(t, buf);
    }

    #[test]
    fn values_not_degenerate() {
        let t = InputSynth::new(3).tensor(10000);
        let mean: f32 = t.iter().sum::<f32>() / t.len() as f32;
        assert!(mean.abs() < 0.05, "mean {mean}");
        let frac_pos = t.iter().filter(|v| **v > 0.0).count() as f32 / t.len() as f32;
        assert!((0.45..0.55).contains(&frac_pos));
    }
}
