//! PJRT client wrapper: compile-once, execute-many.

use std::time::Instant;

use anyhow::{anyhow, Result};

use crate::manifest::{ArtifactEntry, Manifest};

/// A PJRT client plus compile cache.
pub struct Engine {
    client: xla::PjRtClient,
}

impl Engine {
    /// Create the CPU PJRT client (the request-path runtime).
    pub fn cpu() -> Result<Engine> {
        let client = xla::PjRtClient::cpu().map_err(|e| anyhow!("PJRT cpu client: {e:?}"))?;
        Ok(Engine { client })
    }

    /// Backend platform name (e.g. `cpu`).
    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Load and compile one artifact. Compilation happens exactly once
    /// per (model, batch size); the returned handle is reused for every
    /// request batch.
    pub fn load(&self, manifest: &Manifest, entry: &ArtifactEntry) -> Result<LoadedModel> {
        let path = manifest.hlo_path(entry);
        let t0 = Instant::now();
        let proto = xla::HloModuleProto::from_text_file(&path)
            .map_err(|e| anyhow!("parsing HLO text {}: {e:?}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .map_err(|e| anyhow!("compiling {}: {e:?}", path.display()))?;
        Ok(LoadedModel {
            exe,
            entry: entry.clone(),
            compile_ms: t0.elapsed().as_secs_f64() * 1000.0,
        })
    }
}

/// A compiled executable for one `(model, batch_size)` artifact.
pub struct LoadedModel {
    exe: xla::PjRtLoadedExecutable,
    entry: ArtifactEntry,
    /// One-time compile latency (ms), reported in EXPERIMENTS.md.
    pub compile_ms: f64,
}

impl LoadedModel {
    pub fn entry(&self) -> &ArtifactEntry {
        &self.entry
    }

    /// Execute one batch. `input` must hold exactly the artifact's input
    /// element count (batch already included). Returns the flat logits.
    pub fn execute(&self, input: &[f32]) -> Result<Vec<f32>> {
        let want = self.entry.input_elems();
        if input.len() != want {
            return Err(anyhow!(
                "{} bs{}: input has {} elements, artifact wants {}",
                self.entry.model,
                self.entry.batch_size,
                input.len(),
                want
            ));
        }
        let dims: Vec<i64> = self.entry.input_shape.iter().map(|&d| d as i64).collect();
        let lit = xla::Literal::vec1(input)
            .reshape(&dims)
            .map_err(|e| anyhow!("reshape input to {:?}: {e:?}", dims))?;
        let result = self
            .exe
            .execute::<xla::Literal>(&[lit])
            .map_err(|e| anyhow!("execute: {e:?}"))?[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("fetch result: {e:?}"))?;
        // aot.py lowers with return_tuple=True: unwrap the 1-tuple.
        let out = result.to_tuple1().map_err(|e| anyhow!("untuple: {e:?}"))?;
        let values = out.to_vec::<f32>().map_err(|e| anyhow!("to_vec: {e:?}"))?;
        if values.len() != self.entry.output_elems() {
            return Err(anyhow!(
                "output has {} elements, expected {}",
                values.len(),
                self.entry.output_elems()
            ));
        }
        Ok(values)
    }

    /// Execute and time one batch; returns (logits, latency ms).
    pub fn execute_timed(&self, input: &[f32]) -> Result<(Vec<f32>, f64)> {
        let t0 = Instant::now();
        let out = self.execute(input)?;
        Ok((out, t0.elapsed().as_secs_f64() * 1000.0))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn artifacts_dir() -> std::path::PathBuf {
        std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts")
    }

    #[test]
    fn load_and_execute_real_artifact() {
        let dir = artifacts_dir();
        if !dir.join("manifest.json").exists() {
            eprintln!("skipping: run `make artifacts` first");
            return;
        }
        let manifest = Manifest::load(&dir).unwrap();
        let entry = manifest.get("mobv1-025", 1).expect("mobv1-025 bs1 exported");
        let engine = Engine::cpu().unwrap();
        let model = engine.load(&manifest, entry).unwrap();
        assert!(model.compile_ms > 0.0);

        let input = vec![0.5f32; entry.input_elems()];
        let out = model.execute(&input).unwrap();
        assert_eq!(out.len(), entry.output_elems());
        assert!(out.iter().all(|v| v.is_finite()));

        // Determinism: same input, same logits.
        let out2 = model.execute(&input).unwrap();
        assert_eq!(out, out2);

        // Different input must change the logits.
        let input3 = vec![-0.5f32; entry.input_elems()];
        let out3 = model.execute(&input3).unwrap();
        assert_ne!(out, out3);
    }

    #[test]
    fn execute_rejects_wrong_input_len() {
        let dir = artifacts_dir();
        if !dir.join("manifest.json").exists() {
            return;
        }
        let manifest = Manifest::load(&dir).unwrap();
        let entry = manifest.get("mobv1-025", 1).unwrap();
        let engine = Engine::cpu().unwrap();
        let model = engine.load(&manifest, entry).unwrap();
        assert!(model.execute(&[0.0f32; 7]).is_err());
    }
}
