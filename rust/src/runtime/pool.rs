//! Executor pool: the real-mode analogue of multi-tenancy.
//!
//! On the paper's GPU, MTL = N means N TF processes sharing one device.
//! Here each "instance" is a compiled PJRT executable; `execute_round`
//! runs one batch per live instance. On this single-core CPU host the
//! executions time-share exactly like SM-saturated co-location on the
//! P40, which is the honest analogue (DESIGN.md §3).

use std::collections::BTreeMap;

use anyhow::{anyhow, Result};

use crate::manifest::Manifest;
use crate::runtime::engine::{Engine, LoadedModel};
use crate::runtime::input::InputSynth;

/// A pool of co-located instances of one model plus a batch-size cache.
pub struct ExecutorPool {
    engine: Engine,
    manifest: Manifest,
    model: String,
    /// Compiled executables keyed by batch size (compile-once cache).
    compiled: BTreeMap<usize, LoadedModel>,
    /// Number of live co-located instances.
    instances: usize,
    synth: InputSynth,
    input_buf: Vec<f32>,
}

impl ExecutorPool {
    /// Build a pool for `model`, pre-compiling the smallest batch size.
    pub fn new(manifest: Manifest, model: &str) -> Result<Self> {
        let engine = Engine::cpu()?;
        let sizes = manifest.batch_sizes(model);
        if sizes.is_empty() {
            return Err(anyhow!("model {model} not in manifest (have {:?})", manifest.models()));
        }
        let mut pool = ExecutorPool {
            engine,
            manifest,
            model: model.to_string(),
            compiled: BTreeMap::new(),
            instances: 1,
            synth: InputSynth::new(0xD11A5CA1E5),
            input_buf: Vec::new(),
        };
        pool.ensure_compiled(sizes[0])?;
        Ok(pool)
    }

    pub fn model(&self) -> &str {
        &self.model
    }

    pub fn instances(&self) -> usize {
        self.instances
    }

    /// Batch sizes with exported artifacts.
    pub fn available_batch_sizes(&self) -> Vec<usize> {
        self.manifest.batch_sizes(&self.model)
    }

    /// Largest exported batch size (the real-mode `maxBS`).
    pub fn max_batch_size(&self) -> usize {
        *self.available_batch_sizes().last().unwrap_or(&1)
    }

    /// Set the number of co-located instances.
    pub fn set_instances(&mut self, n: usize) {
        self.instances = n.max(1);
    }

    /// Compile (and cache) the artifact that serves batches of `bs`.
    /// Returns the artifact batch size actually used (next size up —
    /// dynamic batch sizing pads to the nearest exported size, which is
    /// how the paper's "dynamic batch sizing with negligible overhead"
    /// maps onto AOT executables).
    pub fn ensure_compiled(&mut self, bs: usize) -> Result<usize> {
        let entry = self
            .manifest
            .best_fit(&self.model, bs)
            .ok_or_else(|| anyhow!("{}: no artifact for bs >= {bs}", self.model))?
            .clone();
        let abs = entry.batch_size;
        if !self.compiled.contains_key(&abs) {
            let loaded = self.engine.load(&self.manifest, &entry)?;
            self.compiled.insert(abs, loaded);
        }
        Ok(abs)
    }

    /// Execute one round: every live instance runs one batch of `bs`
    /// requests. Returns per-instance wall latencies (ms). The wall time
    /// of the round is their sum (single-queue time-sharing).
    pub fn execute_round(&mut self, bs: usize) -> Result<Vec<f64>> {
        let abs = self.ensure_compiled(bs)?;
        let model = &self.compiled[&abs];
        let elems = model.entry().input_elems();
        if self.input_buf.len() != elems {
            self.input_buf.resize(elems, 0.0);
        }
        let mut lats = Vec::with_capacity(self.instances);
        let round0 = std::time::Instant::now();
        for _ in 0..self.instances {
            self.synth.fill(&mut self.input_buf);
            let (_out, _ms) = model.execute_timed(&self.input_buf)?;
            // Under time-sharing every co-located instance's request
            // completes only when its slot finishes; observed latency for
            // instance i is the elapsed wall time so far this round.
            lats.push(round0.elapsed().as_secs_f64() * 1000.0);
        }
        Ok(lats)
    }

    /// One-time compile latencies observed so far, keyed by batch size.
    pub fn compile_report(&self) -> Vec<(usize, f64)> {
        self.compiled.iter().map(|(bs, m)| (*bs, m.compile_ms)).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn manifest() -> Option<Manifest> {
        let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        if dir.join("manifest.json").exists() {
            Some(Manifest::load(&dir).unwrap())
        } else {
            None
        }
    }

    #[test]
    fn pool_round_and_mtl() {
        let Some(m) = manifest() else { return };
        let mut pool = ExecutorPool::new(m, "mobv1-025").unwrap();
        assert_eq!(pool.instances(), 1);
        let l1 = pool.execute_round(1).unwrap();
        assert_eq!(l1.len(), 1);
        assert!(l1[0] > 0.0);

        pool.set_instances(3);
        let l3 = pool.execute_round(1).unwrap();
        assert_eq!(l3.len(), 3);
        // Time-sharing: later instances observe strictly growing latency.
        assert!(l3[0] <= l3[1] && l3[1] <= l3[2]);
    }

    #[test]
    fn pool_pads_to_best_fit() {
        let Some(m) = manifest() else { return };
        let mut pool = ExecutorPool::new(m, "mobv1-025").unwrap();
        // bs=3 is not exported; best-fit should pick 4.
        let abs = pool.ensure_compiled(3).unwrap();
        assert_eq!(abs, 4);
        assert!(pool.execute_round(3).is_ok());
    }

    #[test]
    fn pool_rejects_unknown_model() {
        let Some(m) = manifest() else { return };
        assert!(ExecutorPool::new(m, "not-a-model").is_err());
    }

    #[test]
    fn pool_rejects_oversized_batch() {
        let Some(m) = manifest() else { return };
        let mut pool = ExecutorPool::new(m, "mobv1-025").unwrap();
        let max = pool.max_batch_size();
        assert!(pool.ensure_compiled(max + 1).is_err());
    }
}
