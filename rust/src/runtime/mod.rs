//! PJRT runtime: load AOT HLO-text artifacts and execute them.
//!
//! This is the only place the crate touches XLA. Artifacts are produced
//! once by `make artifacts` (python/jax/pallas); here we parse the HLO
//! text (`HloModuleProto::from_text_file` — text, not serialized proto,
//! reassigns instruction ids and sidesteps the 64-bit-id incompatibility
//! between jax >= 0.5 and xla_extension 0.5.1), compile it once per
//! `(model, batch_size)` on the PJRT CPU client, and execute it from the
//! serving hot path with zero python involvement.

pub mod engine;
pub mod input;
pub mod pool;

pub use engine::{Engine, LoadedModel};
pub use pool::ExecutorPool;
