//! `dnnscaler` — CLI for the DNNScaler reproduction.
//!
//! Subcommands map onto the paper's workflow:
//!
//! * `zoo` — list calibrated paper DNNs and exported AOT artifacts;
//! * `profile` — run the Profiler on one DNN (Table 5 rows);
//! * `job` — run one Table 4 job end-to-end (chosen method vs Clipper);
//! * `jobs` — run the full 30-job workload (Fig. 5 summary);
//! * `fleet` — co-locate several jobs on one shared simulated P40,
//!   closed-loop or (with `--rates`/`--trace`) open-loop with per-member
//!   arrival processes, deadline shedding, and goodput reporting;
//! * `sweep` — throughput/latency vs BS or MTL (Fig. 1 curves);
//! * `serve` — real-mode serving of an AOT artifact over PJRT.
//!
//! `job`, `jobs`, and `serve` accept `--open` plus arrival-shape flags
//! (or `--trace PATH` to replay a recorded arrival log) to serve
//! open-loop through the event-driven engine (queueing delay in every
//! latency, drop accounting under `--queue-cap`, SLO deadline shedding
//! under `--shed`).
//!
//! Argument parsing is hand-rolled (this build is fully offline; see
//! Cargo.toml) — `--key value` flags after the subcommand; each
//! subcommand rejects flags it does not understand.

use anyhow::{anyhow, bail, Result};

use dnnscaler::coordinator::cluster::{
    BestFit, Cluster, DeviceSpec, InterferenceAware, Placement, RoundRobin,
};
use dnnscaler::coordinator::dynamics::{ChurnSchedule, PeriodicReplace, ThresholdAutoscaler};
use dnnscaler::coordinator::job::{paper_job, JobSpec, PAPER_JOBS};
use dnnscaler::coordinator::session::{
    JobOutcome, PolicySpec, RunConfig, ServingSession, DEFAULT_BATCH_TIMEOUT_MS,
};
use dnnscaler::coordinator::{FaultSchedule, Fleet, Method, Profiler, SloClass, SloReport};
#[cfg(feature = "xla")]
use dnnscaler::device::real::RealDevice;
use dnnscaler::gpusim::{Dataset, GpuSim, PartitionMode, PAPER_DNNS};
use dnnscaler::manifest::Manifest;
use dnnscaler::metrics::report::{f1, f2};
use dnnscaler::metrics::Table;
use dnnscaler::workload::ArrivalPattern;

use std::fmt;

const USAGE: &str = "\
dnnscaler — Batching or Multi-Tenancy? (CS.DC 2023 reproduction)

USAGE: dnnscaler <COMMAND> [--flag value ...]

COMMANDS:
  zoo      [--artifacts DIR]
           List calibrated paper DNNs and exported AOT artifacts.
  profile  --dnn NAME [--dataset DS] [--seed N]
           Run the Profiler on one paper DNN (simulated P40).
  job      --id 1..30 [--windows N] [--seed N] [--method M] [--print-trace]
           [open flags]
           Run one Table 4 job: chosen method (default dnnscaler) vs Clipper.
  jobs     [--windows N] [--seed N] [open flags]
           Run the full 30-job workload (Fig. 5 summary).
  fleet    [--ids 1,4,10] [--windows N] [--seed N] [--method M]
           [--rates R1,R2,.. | --trace PATH] [--shed] [--timeout-ms MS]
           [--queue-cap N] [--partition timeshare|mps|mig[:N]]
           [--reservations F1,F2,..] [--slo-class C1,C2,..]
           Serve several jobs concurrently on ONE shared simulated P40
           (shared memory admission + SM contention). With --rates (one
           Poisson rate per member, or one rate for all) or --trace, the
           fleet serves OPEN-LOOP: per-member arrivals through the shared
           event engine, with per-member drop/shed/goodput accounting.
           --partition mps|mig switches the SMs from time-sharing to
           spatial capacity grants (MIG quantizes down to 1/N slices);
           --reservations pins per-member SM fractions (one value or one
           per member; members without one split the rest equally).
           --slo-class gives members service classes (g/gold, s/silver,
           b/best-effort; one value or one per member, needs --rates or
           --trace): lower classes shed earlier and shrink first under
           memory pressure, and the report gains per-class goodput/shed.
  cluster  --devices SPEC1,SPEC2,.. [--placement rr|bestfit|interference]
           [--ids 1,4,10] [--windows N] [--seed N] [--method M]
           [--rates R1,R2,..] [--shed] [--timeout-ms MS] [--queue-cap N]
           [--churn EV1,EV2,..] [--migrate POLICY[:N]] [--autoscale MIN:MAX]
           [--faults EV1,EV2,..] [--mtbf W [--mttr W]]
           [--price P1,P2,..] [--threads N] [--slo-class C1,C2,..]
           Serve jobs across a HETEROGENEOUS pool of devices — the
           scheduling layer above one GPU. Device specs: p40 | p4 | t4,
           optionally :migN to expose the card as N MIG virtual devices
           (each with 1/N of the SMs and memory). --placement picks which
           device each job lands on: rr (round robin), bestfit
           (memory bin packing), interference (separates bursty SM hogs).
           With --rates (one Poisson rate per job, or one for all) jobs
           serve open-loop through the shared event engine; without, the
           cluster serves closed-loop.
           Warehouse dynamics (all need --rates; see docs/dynamics.md):
           --churn schedules mid-run job arrivals/departures, each event
           launch:ID@W[:rRATE] (paper job ID at window W, Poisson RATE
           req/s, default 30) or retire:ID@W; launches pay a model-load
           stall. --migrate re-places live jobs every N windows (default
           4) with the named placement policy, charging each move a
           migration stall. --autoscale grows/shrinks the device pool
           between MIN and MAX on SM pressure, billing device-hours at
           catalogue prices (P40 $1.20/h, T4 $0.53/h, P4 $0.60/h;
           override with --price, one value or one per device) and
           reporting cost per unit goodput.
           Fault injection (needs --rates; see docs/faults.md): --faults
           schedules window-boundary events, each crash:DEV@W (device
           DEV dies at window W: queued work is lost, survivors fail
           over to other devices or wait with exponential backoff),
           degrade:DEV@W:FACTOR:N (DEV runs at FACTOR of its SM capacity
           for N windows), or repair:DEV@W. --mtbf draws per-device
           crash/repair events from exponential MTBF/MTTR distributions
           (both in windows, --mttr default 1) deterministically from
           --seed.
           --threads N shards the per-device event loops across N worker
           threads; output is byte-identical to --threads 1 (the serial
           engine) at every N. --slo-class works as in fleet (needs
           --rates): per-job service classes with class-weighted
           shedding/admission and a per-class report line.
  fuzz     [--cases N] [--seed N]
           Differential fuzzing: N seeded random scenarios (default 200,
           seed 42) spanning fleets and clusters, open and closed
           arrivals, every partition mode, and churn/migration/
           autoscaling dynamics. Each scenario is served by the
           production engine AND by a deliberately naive reference
           executor; snapshots must match byte for byte and pass the
           conservation audit. A mismatch is shrunk to a minimal
           counterexample printed in the canonical corpus format
           (commit it under rust/tests/fuzz_corpus/); exits non-zero.
  sweep    --dnn NAME [--dataset DS] [--knob bs|mtl]
           Throughput/latency sweep over one knob (Fig. 1 curves).
  serve    [--model M] [--slo MS] [--artifacts DIR] [--windows N]
           [--method M] [open flags]
           Serve a real AOT artifact over PJRT.

METHODS (--method): dnnscaler (default) | clipper | queue | combined
  `queue` is the queue-aware proactive scaler: it adds instances on rising
  queue depth / arrival rate / drops BEFORE p95 degrades (open loop).
  `combined` searches batch size AND instance count jointly (the paper's
  Batching x Multi-Tenancy question answered per window, not once).

OPEN-LOOP FLAGS (job, jobs, serve):
  --open                serve open-loop instead of closed-loop
  --rate R              base arrival rate, requests/s (default 50)
  --burst-factor F      rate multiplier during bursts (default 1 = plain Poisson)
  --burst-period S      seconds between burst starts (default 4)
  --burst-len S         burst duration in seconds (default 1)
  --trace PATH          replay a recorded arrival trace (one timestamp in
                        seconds per line; # comments and blanks skipped);
                        implies --open, conflicts with --rate/--burst-*
  --timeout-ms MS       batch-formation timeout (default 5)
  --queue-cap N         bound the request queue; overflow is dropped
  --shed                SLO deadline shedding: drop requests whose queueing
                        delay alone already exceeds the SLO (goodput saver)

Datasets: imagenet caltech sentiment140 imdb ledov dhf1k librispeech
";

/// Tiny `--key value` flag parser (flags without value become `true`).
/// Every subcommand passes its allow-list; unknown flags are an error so
/// a typo like `--windos 10` cannot be silently ignored.
struct Flags(Vec<(String, String)>);

impl Flags {
    fn parse(args: &[String], allowed: &[&str]) -> Result<Flags> {
        let mut out = Vec::new();
        let mut i = 0;
        while i < args.len() {
            let a = &args[i];
            let key = a
                .strip_prefix("--")
                .ok_or_else(|| anyhow!("expected --flag, got {a:?}\n\n{USAGE}"))?;
            if !allowed.contains(&key) {
                let known: Vec<String> = allowed.iter().map(|k| format!("--{k}")).collect();
                bail!(
                    "unknown flag --{key} for this command (allowed: {})",
                    known.join(" ")
                );
            }
            if i + 1 < args.len() && !args[i + 1].starts_with("--") {
                out.push((key.to_string(), args[i + 1].clone()));
                i += 2;
            } else {
                out.push((key.to_string(), "true".to_string()));
                i += 1;
            }
        }
        Ok(Flags(out))
    }

    fn get(&self, key: &str) -> Option<&str> {
        self.0.iter().find(|(k, _)| k == key).map(|(_, v)| v.as_str())
    }

    fn str_or(&self, key: &str, default: &str) -> String {
        self.get(key).unwrap_or(default).to_string()
    }

    fn num_or<T: std::str::FromStr>(&self, key: &str, default: T) -> Result<T> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|_| anyhow!("--{key}: cannot parse {v:?}")),
        }
    }

    fn has(&self, key: &str) -> bool {
        self.get(key).is_some()
    }
}

/// Why a comma-separated numeric list flag (`--rates`, `--reservations`)
/// was rejected. Typed so zero/negative/NaN values are refused at the
/// CLI boundary instead of propagating garbage into the arrival
/// generator or the partition planner.
#[derive(Debug, Clone, PartialEq)]
enum ListParseError {
    Unparseable { flag: &'static str, token: String },
    NotFinite { flag: &'static str, token: String },
    NonPositive { flag: &'static str, value: f64 },
    Empty { flag: &'static str },
}

impl fmt::Display for ListParseError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            ListParseError::Unparseable { flag, token } => {
                write!(f, "--{flag}: {token:?} is not a number")
            }
            ListParseError::NotFinite { flag, token } => {
                write!(f, "--{flag}: {token:?} must be finite (NaN/inf rejected)")
            }
            ListParseError::NonPositive { flag, value } => {
                write!(f, "--{flag}: values must be > 0 (got {value})")
            }
            ListParseError::Empty { flag } => write!(f, "--{flag}: needs at least one value"),
        }
    }
}

impl std::error::Error for ListParseError {}

/// Parse a comma-separated list of strictly positive finite numbers.
fn parse_positive_list(flag: &'static str, s: &str) -> Result<Vec<f64>, ListParseError> {
    let mut out = Vec::new();
    for raw in s.split(',') {
        let token = raw.trim();
        if token.is_empty() {
            return Err(ListParseError::Unparseable { flag, token: raw.to_string() });
        }
        let v: f64 = token
            .parse()
            .map_err(|_| ListParseError::Unparseable { flag, token: token.to_string() })?;
        if !v.is_finite() {
            return Err(ListParseError::NotFinite { flag, token: token.to_string() });
        }
        if v <= 0.0 {
            return Err(ListParseError::NonPositive { flag, value: v });
        }
        out.push(v);
    }
    if out.is_empty() {
        return Err(ListParseError::Empty { flag });
    }
    Ok(out)
}

/// Flags shared by every open-loop-capable subcommand.
const OPEN_FLAGS: &[&str] = &[
    "open",
    "rate",
    "burst-factor",
    "burst-period",
    "burst-len",
    "trace",
    "timeout-ms",
    "queue-cap",
    "shed",
];

/// Parsed open-loop serving shape (None = closed loop).
#[derive(Clone)]
struct OpenCfg {
    pattern: ArrivalPattern,
    timeout_ms: f64,
    queue_cap: Option<usize>,
    shed: bool,
}

fn parse_open(flags: &Flags) -> Result<Option<OpenCfg>> {
    let has_trace = flags.has("trace");
    if !flags.has("open") && !has_trace {
        // The arrival-shape flags mean nothing closed-loop; refuse to
        // silently discard them.
        if let Some(stray) = OPEN_FLAGS.iter().find(|&&k| k != "open" && flags.has(k)) {
            bail!(
                "--{stray} requires --open or --trace PATH (closed-loop serving has no \
                 arrival process)"
            );
        }
        return Ok(None);
    }
    let pattern = if has_trace {
        // The trace IS the arrival process; synthetic-shape flags would
        // be silently overridden, so reject the combination outright.
        for k in ["rate", "burst-factor", "burst-period", "burst-len"] {
            if flags.has(k) {
                bail!("--{k} conflicts with --trace (the trace defines the arrivals)");
            }
        }
        let path = flags.get("trace").unwrap();
        ArrivalPattern::from_trace_file(path).map_err(|e| anyhow!("--trace: {e}"))?
    } else {
        let rate: f64 = flags.num_or("rate", 50.0)?;
        let factor: f64 = flags.num_or("burst-factor", 1.0)?;
        if factor > 1.0 {
            ArrivalPattern::bursty(
                rate,
                factor,
                flags.num_or("burst-period", 4.0)?,
                flags.num_or("burst-len", 1.0)?,
            )
        } else if factor < 1.0 {
            bail!("--burst-factor must be >= 1 (got {factor}); 1 means plain Poisson");
        } else if flags.has("burst-period") || flags.has("burst-len") {
            // Don't silently discard a burst shape the user spelled out.
            bail!("--burst-period/--burst-len have no effect without --burst-factor > 1");
        } else {
            ArrivalPattern::poisson(rate)
        }
    };
    let queue_cap = match flags.get("queue-cap") {
        None => None,
        Some(v) => {
            Some(v.parse().map_err(|_| anyhow!("--queue-cap: cannot parse {v:?}"))?)
        }
    };
    Ok(Some(OpenCfg {
        pattern,
        timeout_ms: flags.num_or("timeout-ms", DEFAULT_BATCH_TIMEOUT_MS)?,
        queue_cap,
        shed: flags.has("shed"),
    }))
}

/// Parse `--method` into the policy it names (default: the paper's full
/// DNNScaler pipeline).
fn parse_method(flags: &Flags) -> Result<PolicySpec<'static>> {
    match flags.str_or("method", "dnnscaler").as_str() {
        "dnnscaler" => Ok(PolicySpec::DnnScaler),
        "clipper" => Ok(PolicySpec::Clipper),
        "queue" => Ok(PolicySpec::QueueAware),
        "combined" => Ok(PolicySpec::Combined),
        other => bail!("--method must be dnnscaler, clipper, queue, or combined (got {other:?})"),
    }
}

fn parse_dataset(s: &str) -> Result<Dataset> {
    Dataset::parse(s).ok_or_else(|| anyhow!("unknown dataset {s:?}"))
}

fn main() -> Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let Some(cmd) = args.first() else {
        print!("{USAGE}");
        return Ok(());
    };
    let rest = &args[1..];
    match cmd.as_str() {
        "zoo" => {
            let flags = Flags::parse(rest, &["artifacts"])?;
            cmd_zoo(&flags.str_or("artifacts", "artifacts"))
        }
        "profile" => {
            let flags = Flags::parse(rest, &["dnn", "dataset", "seed"])?;
            let dnn = flags.get("dnn").ok_or_else(|| anyhow!("profile needs --dnn"))?;
            cmd_profile(dnn, &flags.str_or("dataset", "imagenet"), flags.num_or("seed", 42u64)?)
        }
        "job" => {
            let allowed = [&["id", "windows", "seed", "print-trace", "method"][..], OPEN_FLAGS]
                .concat();
            let flags = Flags::parse(rest, &allowed)?;
            let id = flags.num_or("id", 0u32)?;
            if id == 0 {
                bail!("job needs --id 1..30");
            }
            cmd_job(
                id,
                flags.num_or("windows", 60usize)?,
                flags.num_or("seed", 42u64)?,
                flags.has("print-trace"),
                parse_method(&flags)?,
                parse_open(&flags)?,
            )
        }
        "jobs" => {
            let allowed = [&["windows", "seed"][..], OPEN_FLAGS].concat();
            let flags = Flags::parse(rest, &allowed)?;
            cmd_jobs(
                flags.num_or("windows", 40usize)?,
                flags.num_or("seed", 42u64)?,
                parse_open(&flags)?,
            )
        }
        "fleet" => {
            let flags = Flags::parse(
                rest,
                &[
                    "ids",
                    "windows",
                    "seed",
                    "method",
                    "rates",
                    "trace",
                    "shed",
                    "timeout-ms",
                    "queue-cap",
                    "partition",
                    "reservations",
                    "slo-class",
                ],
            )?;
            cmd_fleet(&flags)
        }
        "cluster" => {
            let flags = Flags::parse(
                rest,
                &[
                    "devices",
                    "placement",
                    "ids",
                    "windows",
                    "seed",
                    "method",
                    "rates",
                    "shed",
                    "timeout-ms",
                    "queue-cap",
                    "churn",
                    "migrate",
                    "autoscale",
                    "faults",
                    "mtbf",
                    "mttr",
                    "price",
                    "threads",
                    "slo-class",
                ],
            )?;
            cmd_cluster(&flags)
        }
        "fuzz" => {
            let flags = Flags::parse(rest, &["cases", "seed"])?;
            cmd_fuzz(flags.num_or("cases", 200usize)?, flags.num_or("seed", 42u64)?)
        }
        "sweep" => {
            let flags = Flags::parse(rest, &["dnn", "dataset", "knob"])?;
            let dnn = flags.get("dnn").ok_or_else(|| anyhow!("sweep needs --dnn"))?;
            cmd_sweep(dnn, &flags.str_or("dataset", "imagenet"), &flags.str_or("knob", "bs"))
        }
        "serve" => {
            let allowed =
                [&["model", "slo", "artifacts", "windows", "method"][..], OPEN_FLAGS].concat();
            let flags = Flags::parse(rest, &allowed)?;
            cmd_serve(
                &flags.str_or("model", "mobv1-025"),
                flags.num_or("slo", 50.0f64)?,
                &flags.str_or("artifacts", "artifacts"),
                flags.num_or("windows", 20usize)?,
                parse_method(&flags)?,
                parse_open(&flags)?,
            )
        }
        "help" | "--help" | "-h" => {
            print!("{USAGE}");
            Ok(())
        }
        other => {
            bail!("unknown command {other:?}\n\n{USAGE}");
        }
    }
}

fn cmd_zoo(artifacts: &str) -> Result<()> {
    let mut t = Table::new(
        "Calibrated paper DNNs (gpusim)",
        &["dnn", "weights(MB)", "bsat", "r1", "prep(ms)", "kappa"],
    );
    for p in PAPER_DNNS {
        t.row(&[
            p.name.into(),
            f1(p.weight_mb),
            f1(p.bsat),
            f2(p.r1),
            f2(p.t_prep_ms),
            f2(p.kappa),
        ]);
    }
    print!("{}", t.render());

    match Manifest::load(artifacts) {
        Ok(m) => {
            let mut t = Table::new(
                "AOT artifacts (real mode)",
                &["model", "batch sizes", "params", "analogue"],
            );
            for model in m.models() {
                let sizes = m.batch_sizes(&model);
                let e = m.get(&model, sizes[0]).unwrap();
                t.row(&[
                    model.clone(),
                    format!("{sizes:?}"),
                    e.param_count.to_string(),
                    e.paper_analogue.clone(),
                ]);
            }
            print!("{}", t.render());
        }
        Err(e) => println!("(no artifacts: {e})"),
    }
    Ok(())
}

fn cmd_profile(dnn: &str, dataset: &str, seed: u64) -> Result<()> {
    let ds = parse_dataset(dataset)?;
    let mut sim = GpuSim::for_paper_dnn(dnn, ds, seed)
        .ok_or_else(|| anyhow!("unknown DNN {dnn:?} (see `dnnscaler zoo`)"))?;
    let out = Profiler::default().run(&mut sim).map_err(|e| anyhow!(e.to_string()))?;
    println!("DNN {dnn} on {}:", ds.name());
    println!("  base throughput  {:>9.2} inf/s (lat {:.2} ms)", out.thr_base, out.lat_base_ms);
    println!("  BS=32 throughput {:>9.2} inf/s -> TI_B  = {:>7.2}%", out.thr_batch, out.ti_b);
    println!("  MTL=8 throughput {:>9.2} inf/s -> TI_MT = {:>7.2}%", out.thr_mt, out.ti_mt);
    println!("  method: {:?} (profiling overhead {:.0} ms)", out.method, out.overhead_ms);
    Ok(())
}

/// Run one session on a fresh simulator through the event-driven API.
fn run_session(
    job: &JobSpec,
    cfg: RunConfig,
    seed: u64,
    spec: PolicySpec<'static>,
    open: Option<&OpenCfg>,
) -> Result<JobOutcome> {
    let sim = GpuSim::for_paper_dnn(job.dnn, job.dataset, seed)
        .ok_or_else(|| anyhow!("unknown DNN {:?}", job.dnn))?;
    let mut b =
        ServingSession::builder().config(cfg).job(job).device(sim).policy(spec).seed(seed);
    if let Some(o) = open {
        b = b
            .arrivals(o.pattern.clone())
            .batch_timeout_ms(o.timeout_ms)
            .shed_deadline(o.shed);
        if let Some(cap) = o.queue_cap {
            b = b.queue_capacity(cap);
        }
    }
    b.build()
        .map_err(|e| anyhow!(e.to_string()))?
        .run()
        .map_err(|e| anyhow!(e.to_string()))
}

fn run_job_pair(
    job: &JobSpec,
    windows: usize,
    seed: u64,
    spec: PolicySpec<'static>,
    open: Option<&OpenCfg>,
) -> Result<(JobOutcome, JobOutcome)> {
    let cfg = RunConfig::windows(windows, 20);
    let chosen = run_session(job, cfg.clone(), seed, spec, open)?;
    let clipper = run_session(job, cfg, seed + 1, PolicySpec::Clipper, open)?;
    Ok((chosen, clipper))
}

fn cmd_job(
    id: u32,
    windows: usize,
    seed: u64,
    print_trace: bool,
    spec: PolicySpec<'static>,
    open: Option<OpenCfg>,
) -> Result<()> {
    let job = paper_job(id).ok_or_else(|| anyhow!("job id must be 1..=30"))?;
    let (chosen, clipper) = run_job_pair(job, windows, seed, spec, open.as_ref())?;
    println!(
        "Job {} ({} on {}, SLO {} ms): paper method {:?}{}",
        job.id,
        job.dnn,
        job.dataset.name(),
        job.slo_ms,
        job.paper_method,
        if open.is_some() { "  [open-loop]" } else { "" }
    );
    for o in [&chosen, &clipper] {
        println!(
            "  {:<11} thr {:>9.2} inf/s  p95 {:>8.2} ms  SLO-attain {:>5.1}%  power {:>6.1} W  knob bs={} mtl={}",
            o.controller,
            o.throughput,
            o.p95_ms,
            o.slo_attainment * 100.0,
            o.power_w,
            o.steady_bs,
            o.steady_mtl
        );
        if open.is_some() {
            println!(
                "  {:<11} queue peak {:>4}  dropped {:>5}  shed {:>5}  goodput {:>8.2} inf/s  steady attain {:>5.1}%",
                "",
                o.queue_peak,
                o.drops,
                o.dropped_deadline,
                o.goodput,
                o.steady_attainment * 100.0
            );
        }
    }
    println!(
        "  speedup vs clipper: {:.2}x (profiler method: {})",
        chosen.throughput / clipper.throughput,
        chosen.method.map_or_else(|| "-".to_string(), |m| format!("{m:?}"))
    );
    if print_trace {
        for r in &chosen.trace {
            println!(
                "    w{:03} bs={} mtl={} p95={:.2} slo={:.0} thr={:.1} queue={} drops={} shed={}",
                r.window,
                r.bs,
                r.mtl,
                r.p95_ms,
                r.slo_ms,
                r.throughput,
                r.queue_peak,
                r.drops,
                r.drops_deadline
            );
        }
    }
    Ok(())
}

fn cmd_jobs(windows: usize, seed: u64, open: Option<OpenCfg>) -> Result<()> {
    let title = if open.is_some() {
        "All 30 jobs, open-loop: DNNScaler vs Clipper"
    } else {
        "All 30 jobs: DNNScaler vs Clipper (Fig. 5)"
    };
    let mut t = Table::new(
        title,
        &[
            "job",
            "dnn",
            "method",
            "paper",
            "knob",
            "scaler thr",
            "clipper thr",
            "speedup",
            "attain%",
            "goodput",
        ],
    );
    let mut sum_gain = 0.0;
    let mut max_gain: (f64, u32) = (0.0, 0);
    let mut method_hits = 0;
    for job in PAPER_JOBS {
        let (scaler, clipper) =
            run_job_pair(job, windows, seed, PolicySpec::DnnScaler, open.as_ref())?;
        let gain = scaler.throughput / clipper.throughput;
        sum_gain += gain;
        if gain > max_gain.0 {
            max_gain = (gain, job.id);
        }
        let method = scaler.method.unwrap();
        if method == job.paper_method {
            method_hits += 1;
        }
        let knob = match method {
            Method::Batching => format!("BS={}", scaler.steady_bs),
            Method::MultiTenancy => format!("MTL={}", scaler.steady_mtl),
        };
        t.row(&[
            job.id.to_string(),
            job.dnn.into(),
            method.short().into(),
            job.paper_method.short().into(),
            knob,
            f1(scaler.throughput),
            f1(clipper.throughput),
            f2(gain),
            f1(scaler.slo_attainment * 100.0),
            f1(scaler.goodput),
        ]);
    }
    print!("{}", t.render());
    println!(
        "method agreement with Table 4: {}/30; mean speedup {:.2}x; max {:.2}x (job {})",
        method_hits,
        sum_gain / PAPER_JOBS.len() as f64,
        max_gain.0,
        max_gain.1
    );
    Ok(())
}

fn cmd_fleet(flags: &Flags) -> Result<()> {
    let ids = flags.str_or("ids", "1,4,10");
    let windows = flags.num_or("windows", 30usize)?;
    let seed = flags.num_or("seed", 42u64)?;
    let shed = flags.has("shed");
    let timeout_ms: f64 = flags.num_or("timeout-ms", DEFAULT_BATCH_TIMEOUT_MS)?;
    let queue_cap: Option<usize> = match flags.get("queue-cap") {
        None => None,
        Some(v) => Some(v.parse().map_err(|_| anyhow!("--queue-cap: cannot parse {v:?}"))?),
    };

    let mut jobs = Vec::new();
    for tok in ids.split(',') {
        let id: u32 = tok.trim().parse().map_err(|_| anyhow!("--ids: bad job id {tok:?}"))?;
        jobs.push(paper_job(id).ok_or_else(|| anyhow!("job id must be 1..=30, got {id}"))?);
    }

    // Open-loop fleet: per-member Poisson rates or one shared trace file.
    // Zero/negative/NaN rates are refused here with a typed error rather
    // than handed to the Poisson generator.
    let rates: Option<Vec<f64>> = match flags.get("rates") {
        None => None,
        Some(s) => Some(parse_positive_list("rates", s)?),
    };
    if let Some(rs) = &rates {
        if rs.len() != 1 && rs.len() != jobs.len() {
            bail!(
                "--rates needs 1 value or one per member ({} jobs, {} rates)",
                jobs.len(),
                rs.len()
            );
        }
        if flags.has("trace") {
            bail!("--rates conflicts with --trace (pick one arrival source)");
        }
    }
    let trace_pattern: Option<ArrivalPattern> = match flags.get("trace") {
        None => None,
        Some(path) => {
            Some(ArrivalPattern::from_trace_file(path).map_err(|e| anyhow!("--trace: {e}"))?)
        }
    };
    let open = rates.is_some() || trace_pattern.is_some();
    if !open && (shed || flags.has("timeout-ms") || flags.has("queue-cap")) {
        bail!("--shed/--timeout-ms/--queue-cap need --rates or --trace (open-loop fleet)");
    }
    let classes: Option<Vec<SloClass>> = match flags.get("slo-class") {
        None => None,
        Some(s) => Some(parse_slo_classes(s)?),
    };
    if classes.is_some() && !open {
        bail!("--slo-class needs --rates or --trace (open-loop fleet)");
    }

    // Spatial SM partitioning: --partition selects the mode, optional
    // --reservations pins per-member fractions (one value or one per
    // member). Values are validated here (typed list errors) and again
    // by the builder's partition planner.
    let partition = match flags.get("partition") {
        None => PartitionMode::TimeShare,
        Some(s) => PartitionMode::parse(s).ok_or_else(|| {
            anyhow!("--partition must be timeshare, mps, or mig[:SLICES] (got {s:?})")
        })?,
    };
    let reservations: Option<Vec<f64>> = match flags.get("reservations") {
        None => None,
        Some(s) => Some(parse_positive_list("reservations", s)?),
    };
    if reservations.is_some() && !partition.is_spatial() {
        bail!("--reservations needs --partition mps or mig (timeshare has no partitions)");
    }

    let mut b = Fleet::builder()
        .windows(windows)
        .rounds_per_window(20)
        .seed(seed)
        .partition_mode(partition);
    let picked: Vec<u32> = jobs.iter().map(|j| j.id).collect();
    for (i, job) in jobs.iter().enumerate() {
        // Every member serves under the same --method; PolicySpec is not
        // Clone (Custom holds a boxed policy), so construct one per member.
        let spec = parse_method(flags)?;
        if open {
            let pattern = match (&rates, &trace_pattern) {
                (Some(rs), _) => {
                    ArrivalPattern::poisson(if rs.len() == 1 { rs[0] } else { rs[i] })
                }
                (None, Some(p)) => p.clone(),
                (None, None) => unreachable!("open implies rates or trace"),
            };
            b = b
                .job_with_arrivals(job, spec, pattern)
                .batch_timeout_ms(timeout_ms)
                .shed_deadline(shed);
            if let Some(cap) = queue_cap {
                b = b.queue_capacity(cap);
            }
        } else {
            b = b.job(job, spec);
        }
    }
    // The whole-list form: the builder broadcasts one value or matches
    // one per member, and rejects any other count with a typed
    // ConfigError (a longer list used to be possible to truncate here).
    if let Some(rs) = &reservations {
        b = b.sm_reservations(rs);
    }
    if let Some(cs) = &classes {
        b = b.slo_classes(cs);
    }
    let out = b
        .build()
        .map_err(|e| anyhow!(e.to_string()))?
        .run()
        .map_err(|e| anyhow!(e.to_string()))?;

    let partition_tag = if partition.is_spatial() {
        format!(" [partition {partition}]")
    } else {
        String::new()
    };
    let title = format!(
        "Fleet: jobs {picked:?} sharing one simulated P40{}{partition_tag}",
        if open { " [open-loop]" } else { "" },
    );
    let mut t = Table::new(
        &title,
        &[
            "job", "dnn", "policy", "knob", "arr/s", "thr", "goodput", "p95(ms)", "attain%",
            "drop", "shed",
        ],
    );
    for m in &out.members {
        let knob = format!("bs={} mtl={}", m.steady_bs, m.steady_mtl);
        t.row(&[
            m.job_id.to_string(),
            m.dnn.clone(),
            m.controller.clone(),
            knob,
            f1(m.mean_arrival_rate()),
            f1(m.throughput),
            f1(m.goodput),
            f2(m.p95_ms),
            f1(m.slo_attainment * 100.0),
            m.drops.to_string(),
            m.dropped_deadline.to_string(),
        ]);
    }
    print!("{}", t.render());
    println!(
        "fleet total {:.1} inf/s (goodput {:.1}) | peak mem {:.0}/{:.0} MB | peak SM contention {:.2} | admission clamps {}",
        out.total_throughput,
        out.total_goodput,
        out.peak_mem_mb,
        out.mem_capacity_mb,
        out.peak_contention,
        out.admission_clamps
    );
    if let Some(grants) = out.grant_trace.last() {
        let shares: Vec<String> = grants.iter().map(|g| format!("{g:.3}")).collect();
        println!("final SM grants ({}): [{}]", out.partition, shares.join(", "));
    }
    if let Some(r) = &out.slo {
        println!("{}", slo_line(r));
    }
    Ok(())
}

/// Parse `--slo-class g,s,b,..` into service classes (full names work
/// too); unknown tokens surface the typed parse error verbatim.
fn parse_slo_classes(s: &str) -> Result<Vec<SloClass>> {
    s.split(',')
        .map(|tok| SloClass::parse(tok).map_err(|e| anyhow!("--slo-class: {e}")))
        .collect()
}

/// One-line per-class goodput/shed report, printed only on classed runs
/// so unclassed CLI output stays byte-identical.
fn slo_line(r: &SloReport) -> String {
    let parts: Vec<String> = SloClass::ALL
        .iter()
        .map(|&c| {
            let s = r.class(c);
            format!("{} x{} goodput {:.1} shed {}", c.name(), s.members, s.goodput, s.shed)
        })
        .collect();
    format!("slo: {}", parts.join(" | "))
}

/// Parse `--placement` into the placer it names.
fn parse_placement(s: &str) -> Result<Box<dyn Placement>> {
    match s {
        "rr" | "roundrobin" | "round-robin" => Ok(Box::new(RoundRobin::new())),
        "bestfit" | "best-fit" => Ok(Box::new(BestFit::new())),
        "interference" | "interference-aware" => Ok(Box::new(InterferenceAware::new())),
        other => bail!("--placement must be rr, bestfit, or interference (got {other:?})"),
    }
}

/// Parse `--churn launch:ID@W[:rRATE],retire:ID@W` into a schedule.
/// Launched jobs serve with the subcommand's `--method` policy and
/// Poisson arrivals (RATE requests/s, default 30).
fn parse_churn(flags: &Flags, s: &str) -> Result<ChurnSchedule<'static>> {
    let mut churn = ChurnSchedule::new();
    for tok in s.split(',') {
        let tok = tok.trim();
        let (kind, rest) = tok
            .split_once(':')
            .ok_or_else(|| anyhow!("--churn: {tok:?} is not launch:ID@W or retire:ID@W"))?;
        let (idw, rate_tok) = match rest.split_once(':') {
            Some((idw, r)) => (idw, Some(r)),
            None => (rest, None),
        };
        let (id_s, w_s) = idw
            .split_once('@')
            .ok_or_else(|| anyhow!("--churn: {tok:?} is missing @WINDOW"))?;
        let id: u32 =
            id_s.parse().map_err(|_| anyhow!("--churn: bad job id {id_s:?} in {tok:?}"))?;
        let window: usize =
            w_s.parse().map_err(|_| anyhow!("--churn: bad window {w_s:?} in {tok:?}"))?;
        match kind {
            "launch" => {
                let rate: f64 = match rate_tok {
                    None => 30.0,
                    Some(r) => {
                        let r = r.strip_prefix('r').ok_or_else(|| {
                            anyhow!("--churn: launch rate must look like r50 (got {r:?})")
                        })?;
                        r.parse().map_err(|_| anyhow!("--churn: bad rate {r:?} in {tok:?}"))?
                    }
                };
                let job =
                    paper_job(id).ok_or_else(|| anyhow!("--churn: job id must be 1..=30, got {id}"))?;
                churn = churn.launch(window, job, parse_method(flags)?, ArrivalPattern::poisson(rate));
            }
            "retire" => {
                if rate_tok.is_some() {
                    bail!("--churn: retire takes no rate ({tok:?})");
                }
                churn = churn.retire(window, id);
            }
            other => bail!("--churn: unknown event {other:?} (launch or retire)"),
        }
    }
    Ok(churn)
}

/// Parse `--faults crash:DEV@W,degrade:DEV@W:FACTOR:N,repair:DEV@W` into
/// a schedule; device indices and window bounds are validated against
/// the pool by the cluster builder.
fn parse_faults(s: &str) -> Result<FaultSchedule> {
    let mut sched = FaultSchedule::new();
    for tok in s.split(',') {
        let tok = tok.trim();
        let (kind, rest) = tok
            .split_once(':')
            .ok_or_else(|| anyhow!("--faults: {tok:?} is not crash:DEV@W, degrade:DEV@W:FACTOR:N, or repair:DEV@W"))?;
        let mut parts = rest.split(':');
        let at = parts.next().unwrap_or("");
        let (d_s, w_s) = at
            .split_once('@')
            .ok_or_else(|| anyhow!("--faults: {tok:?} is missing DEV@WINDOW"))?;
        let device: usize =
            d_s.parse().map_err(|_| anyhow!("--faults: bad device {d_s:?} in {tok:?}"))?;
        let window: usize =
            w_s.parse().map_err(|_| anyhow!("--faults: bad window {w_s:?} in {tok:?}"))?;
        let extras: Vec<&str> = parts.collect();
        sched = match (kind, extras.as_slice()) {
            ("crash", []) => sched.crash(device, window),
            ("repair", []) => sched.repair(device, window),
            ("degrade", [f_s, n_s]) => {
                let factor: f64 = f_s
                    .parse()
                    .map_err(|_| anyhow!("--faults: bad factor {f_s:?} in {tok:?}"))?;
                let for_windows: usize = n_s
                    .parse()
                    .map_err(|_| anyhow!("--faults: bad duration {n_s:?} in {tok:?}"))?;
                sched.degrade(device, window, factor, for_windows)
            }
            ("degrade", _) => {
                bail!("--faults: degrade wants degrade:DEV@W:FACTOR:WINDOWS ({tok:?})")
            }
            ("crash" | "repair", _) => {
                bail!("--faults: {kind} takes no extra fields ({tok:?})")
            }
            (other, _) => {
                bail!("--faults: unknown fault {other:?} (crash, degrade, or repair)")
            }
        };
    }
    Ok(sched)
}

fn cmd_cluster(flags: &Flags) -> Result<()> {
    let devices_arg = flags
        .get("devices")
        .ok_or_else(|| anyhow!("cluster needs --devices SPEC1,SPEC2,.. (e.g. p40,t4:mig2)"))?;
    let specs = DeviceSpec::parse_list(devices_arg).map_err(|e| anyhow!(e.to_string()))?;
    let placement = parse_placement(&flags.str_or("placement", "rr"))?;
    let ids = flags.str_or("ids", "1,4,10");
    let windows = flags.num_or("windows", 20usize)?;
    let seed = flags.num_or("seed", 42u64)?;
    let shed = flags.has("shed");
    let timeout_ms: f64 = flags.num_or("timeout-ms", DEFAULT_BATCH_TIMEOUT_MS)?;
    let queue_cap: Option<usize> = match flags.get("queue-cap") {
        None => None,
        Some(v) => Some(v.parse().map_err(|_| anyhow!("--queue-cap: cannot parse {v:?}"))?),
    };

    let mut jobs = Vec::new();
    for tok in ids.split(',') {
        let id: u32 = tok.trim().parse().map_err(|_| anyhow!("--ids: bad job id {tok:?}"))?;
        jobs.push(paper_job(id).ok_or_else(|| anyhow!("job id must be 1..=30, got {id}"))?);
    }
    let rates: Option<Vec<f64>> = match flags.get("rates") {
        None => None,
        Some(s) => Some(parse_positive_list("rates", s)?),
    };
    if rates.is_none() && (shed || flags.has("timeout-ms") || flags.has("queue-cap")) {
        bail!("--shed/--timeout-ms/--queue-cap need --rates (open-loop cluster)");
    }
    let classes: Option<Vec<SloClass>> = match flags.get("slo-class") {
        None => None,
        Some(s) => Some(parse_slo_classes(s)?),
    };
    if classes.is_some() && rates.is_none() {
        bail!("--slo-class needs --rates (open-loop cluster)");
    }
    let dynamic = flags.has("churn")
        || flags.has("migrate")
        || flags.has("autoscale")
        || flags.has("faults")
        || flags.has("mtbf")
        || flags.has("mttr");
    if dynamic && rates.is_none() {
        bail!("--churn/--migrate/--autoscale/--faults/--mtbf need --rates (open-loop cluster)");
    }
    if flags.has("mttr") && !flags.has("mtbf") {
        bail!("--mttr needs --mtbf (stochastic fault injection)");
    }

    let mut b = Cluster::builder()
        .windows(windows)
        .rounds_per_window(20)
        .seed(seed)
        .threads(flags.num_or("threads", 1usize)?)
        .placement(placement);
    for spec in &specs {
        b = b.device_spec(spec);
    }
    for job in &jobs {
        let spec = parse_method(flags)?;
        b = b.job(job, spec);
        if rates.is_some() {
            b = b.batch_timeout_ms(timeout_ms).shed_deadline(shed);
            if let Some(cap) = queue_cap {
                b = b.queue_capacity(cap);
            }
        }
    }
    // One rate (broadcast) or one per job; the builder refuses any
    // other count with a typed ConfigError and turns every job open-loop.
    if let Some(rs) = &rates {
        b = b.poisson_rates(rs);
    }
    if let Some(cs) = &classes {
        b = b.slo_classes(cs);
    }
    // Dynamics: any of --churn/--migrate/--autoscale switches the run
    // onto the window-boundary dynamic path.
    if let Some(s) = flags.get("churn") {
        b = b.churn(parse_churn(flags, s)?);
    }
    if let Some(s) = flags.get("migrate") {
        let (name, every) = match s.split_once(':') {
            None => (s, 4usize),
            Some((n, e)) => {
                (n, e.parse().map_err(|_| anyhow!("--migrate: bad period {e:?}"))?)
            }
        };
        b = match name {
            "rr" | "roundrobin" | "round-robin" => {
                b.placement_policy(PeriodicReplace::new(RoundRobin::new(), every))
            }
            "bestfit" | "best-fit" => {
                b.placement_policy(PeriodicReplace::new(BestFit::new(), every))
            }
            "interference" | "interference-aware" => {
                b.placement_policy(PeriodicReplace::new(InterferenceAware::new(), every))
            }
            other => bail!("--migrate must be rr, bestfit, or interference (got {other:?})"),
        };
    }
    if let Some(s) = flags.get("autoscale") {
        let (min_s, max_s) = s
            .split_once(':')
            .ok_or_else(|| anyhow!("--autoscale wants MIN:MAX (got {s:?})"))?;
        let min: usize =
            min_s.parse().map_err(|_| anyhow!("--autoscale: bad MIN {min_s:?}"))?;
        let max: usize =
            max_s.parse().map_err(|_| anyhow!("--autoscale: bad MAX {max_s:?}"))?;
        b = b.autoscaler(ThresholdAutoscaler::new(min, max));
    }
    if let Some(s) = flags.get("faults") {
        b = b.faults(parse_faults(s)?);
    }
    if let Some(m) = flags.get("mtbf") {
        let mtbf: f64 = m.parse().map_err(|_| anyhow!("--mtbf: cannot parse {m:?}"))?;
        let mttr: f64 = match flags.get("mttr") {
            None => 1.0,
            Some(t) => t.parse().map_err(|_| anyhow!("--mttr: cannot parse {t:?}"))?,
        };
        b = b.stochastic_faults(mtbf, mttr);
    }
    if let Some(s) = flags.get("price") {
        b = b.prices(&parse_positive_list("price", s)?);
    }
    let cluster = b.build().map_err(|e| anyhow!(e.to_string()))?;
    let out = cluster.run().map_err(|e| anyhow!(e.to_string()))?;

    let open = rates.is_some();
    let picked: Vec<u32> = jobs.iter().map(|j| j.id).collect();
    let title = format!(
        "Cluster: jobs {picked:?} on {} device(s) [placement {}]{}",
        out.devices.len(),
        out.placement,
        if open { " [open-loop]" } else { "" },
    );
    let mut t = Table::new(
        &title,
        &[
            "device", "sm", "mem(MB)", "job", "dnn", "policy", "knob", "thr", "goodput",
            "p95(ms)", "attain%",
        ],
    );
    for dev in &out.devices {
        if dev.fleet.members.is_empty() {
            t.row(&[
                dev.device.name.clone(),
                f2(dev.device.perf_fraction),
                format!("{:.0}", dev.device.mem_mb),
                "-".into(),
                "(idle)".into(),
                "-".into(),
                "-".into(),
                "-".into(),
                "-".into(),
                "-".into(),
                "-".into(),
            ]);
            continue;
        }
        for (m, &j) in dev.fleet.members.iter().zip(&dev.jobs) {
            t.row(&[
                dev.device.name.clone(),
                f2(dev.device.perf_fraction),
                format!("{:.0}", dev.device.mem_mb),
                format!("{} (#{j})", m.job_id),
                m.dnn.clone(),
                m.controller.clone(),
                format!("bs={} mtl={}", m.steady_bs, m.steady_mtl),
                f1(m.throughput),
                f1(m.goodput),
                f2(m.p95_ms),
                f1(m.slo_attainment * 100.0),
            ]);
        }
    }
    print!("{}", t.render());
    println!(
        "cluster total {:.1} inf/s (goodput {:.1}) | assignment {:?}",
        out.total_throughput, out.total_goodput, out.assignment
    );
    if let Some(r) = &out.slo {
        println!("{}", slo_line(r));
    }
    if let Some(dy) = &out.dynamics {
        println!(
            "dynamics: {} launch(es) ({} failed), {} retire(s), {} migration(s) \
             ({:.0} ms stall, {} proposal(s) rejected), {} scale-up(s) / {} scale-down(s)",
            dy.launches,
            dy.failed_launches,
            dy.retires,
            dy.migrations,
            dy.migration_stall_ms,
            dy.rejected_proposals,
            dy.scale_ups,
            dy.scale_downs,
        );
        println!(
            "billing: {:.3} device-hours, ${:.4}{} | pool size per window {:?}",
            dy.device_hours,
            dy.cost_usd,
            dy.cost_per_goodput
                .map_or(String::new(), |c| format!(" (${c:.5} per inf/s of goodput)")),
            dy.pool_trace,
        );
        if let Some(fo) = &dy.faults {
            println!(
                "faults: {} crash(es), {} degrade(s), {} repair(s) | {} failover(s) \
                 ({:.0} ms stall), {} request(s) lost, {} job(s) deferred | \
                 healthy devices per window {:?}",
                fo.crashes,
                fo.degrades,
                fo.repairs,
                fo.failovers,
                fo.failover_stall_ms,
                fo.dropped_failure,
                fo.deferred_jobs,
                fo.pool_health,
            );
        }
    }
    for dev in &out.devices {
        if !dev.fleet.members.is_empty() {
            println!(
                "  {}: {:.1} inf/s, peak mem {:.0}/{:.0} MB, peak SM pressure {:.2}, clamps {}",
                dev.device.name,
                dev.fleet.total_throughput,
                dev.fleet.peak_mem_mb,
                dev.fleet.mem_capacity_mb,
                dev.fleet.peak_contention,
                dev.fleet.admission_clamps
            );
        }
    }
    Ok(())
}

fn cmd_fuzz(cases: usize, seed: u64) -> Result<()> {
    use dnnscaler::coordinator::testkit::{class_name, describe_failure, run_fuzz, NUM_CLASSES};

    println!("differential fuzz: {cases} case(s), seed {seed}");
    let report = run_fuzz(cases, seed, None);
    let mut t = Table::new("Scenario classes", &["class", "buildable"]);
    for (class, &built) in report.built.iter().enumerate() {
        t.row(&[class_name(class).to_string(), built.to_string()]);
    }
    print!("{}", t.render());
    let total: usize = report.built.iter().sum();
    println!(
        "{} buildable scenario(s) across {} class(es), {} mismatch(es)",
        total,
        NUM_CLASSES,
        report.failures.len()
    );
    if report.failures.is_empty() {
        println!("fast and reference executors agree on every case; audits clean");
        return Ok(());
    }
    for f in &report.failures {
        println!("\n{}", describe_failure(f));
    }
    bail!("{} of {cases} scenario(s) mismatched", report.failures.len());
}

fn cmd_sweep(dnn: &str, dataset: &str, knob: &str) -> Result<()> {
    let ds = parse_dataset(dataset)?;
    let sim = GpuSim::for_paper_dnn(dnn, ds, 0).ok_or_else(|| anyhow!("unknown DNN {dnn:?}"))?;
    match knob {
        "bs" => {
            let mut t = Table::new(
                &format!("{dnn}: Batching sweep (Fig. 1a/1c)"),
                &["bs", "throughput", "latency(ms)", "power(W)", "sm util"],
            );
            for bs in [1u32, 2, 4, 8, 16, 32, 64, 128] {
                t.row(&[
                    bs.to_string(),
                    f1(sim.throughput(bs, 1)),
                    f2(sim.mean_batch_latency_ms(bs, 1)),
                    f1(sim.power_w(bs, 1)),
                    f2(sim.sm_utilization(bs, 1)),
                ]);
            }
            print!("{}", t.render());
        }
        "mtl" => {
            let mut t = Table::new(
                &format!("{dnn}: Multi-Tenancy sweep (Fig. 1b/1d)"),
                &["mtl", "throughput", "latency(ms)", "power(W)", "sm util"],
            );
            for n in 1..=10u32 {
                t.row(&[
                    n.to_string(),
                    f1(sim.throughput(1, n)),
                    f2(sim.mean_batch_latency_ms(1, n)),
                    f1(sim.power_w(1, n)),
                    f2(sim.sm_utilization(1, n)),
                ]);
            }
            print!("{}", t.render());
        }
        other => return Err(anyhow!("knob must be `bs` or `mtl`, got {other:?}")),
    }
    Ok(())
}

/// Real-mode serving needs the PJRT runtime; without the `xla` feature
/// there is no device to open, so the subcommand fails with a pointer at
/// the feature flag instead of silently simulating.
#[cfg(not(feature = "xla"))]
fn cmd_serve(
    _model: &str,
    _slo: f64,
    _artifacts: &str,
    _windows: usize,
    _spec: PolicySpec<'static>,
    _open: Option<OpenCfg>,
) -> Result<()> {
    bail!(
        "real-mode serving requires the `xla` feature \
         (rebuild with `cargo build --features xla`)"
    )
}

#[cfg(feature = "xla")]
fn cmd_serve(
    model: &str,
    slo: f64,
    artifacts: &str,
    windows: usize,
    spec: PolicySpec<'static>,
    open: Option<OpenCfg>,
) -> Result<()> {
    let mut dev = RealDevice::open(artifacts, model)?;
    println!("loaded {model} (max BS {})", dev.max_batch_size());
    let job = JobSpec {
        id: 0,
        dnn: Box::leak(model.to_string().into_boxed_str()),
        dataset: Dataset::Synthetic,
        slo_ms: slo,
        paper_method: Method::Batching,
        paper_steady: dnnscaler::coordinator::job::SteadyKnob::Bs(1),
    };
    let max_bs = dev.max_batch_size();
    let cfg = RunConfig {
        windows,
        rounds_per_window: 10,
        max_bs,
        probe_bs: 8.min(max_bs),
        probe_mtl: 4,
        ..Default::default()
    };
    let mut b = ServingSession::builder()
        .config(cfg)
        .job(&job)
        .device(&mut dev)
        .policy(spec);
    if let Some(o) = &open {
        b = b
            .arrivals(o.pattern.clone())
            .batch_timeout_ms(o.timeout_ms)
            .shed_deadline(o.shed);
        if let Some(cap) = o.queue_cap {
            b = b.queue_capacity(cap);
        }
    }
    let out = b
        .build()
        .map_err(|e| anyhow!(e.to_string()))?
        .run()
        .map_err(|e| anyhow!(e.to_string()))?;
    println!(
        "served: {} (method {}), steady bs={} mtl={}, throughput {:.1} inf/s, p95 {:.2} ms, SLO attainment {:.1}%",
        out.controller,
        out.method.map_or_else(|| "-".to_string(), |m| format!("{m:?}")),
        out.steady_bs,
        out.steady_mtl,
        out.throughput,
        out.p95_ms,
        out.slo_attainment * 100.0
    );
    if open.is_some() {
        println!(
            "open-loop: queue peak {}, dropped {}, shed {}, goodput {:.1} inf/s",
            out.queue_peak, out.drops, out.dropped_deadline, out.goodput
        );
    }
    for (bs, ms) in dev.pool().compile_report() {
        println!("  compiled bs={bs} in {ms:.0} ms (once)");
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::{
        parse_method, parse_open, parse_positive_list, Flags, ListParseError, PolicySpec,
        OPEN_FLAGS,
    };

    fn flags(args: &[&str]) -> Flags {
        let owned: Vec<String> = args.iter().map(|s| s.to_string()).collect();
        Flags::parse(&owned, &[&["method"][..], OPEN_FLAGS].concat()).unwrap()
    }

    #[test]
    fn open_flags_require_open_or_trace() {
        let err = parse_open(&flags(&["--rate", "80"])).unwrap_err().to_string();
        assert!(err.contains("--open or --trace"), "{err}");
        let err = parse_open(&flags(&["--shed"])).unwrap_err().to_string();
        assert!(err.contains("--shed"), "{err}");
        assert!(parse_open(&flags(&[])).unwrap().is_none());
    }

    #[test]
    fn trace_conflicts_with_synthetic_shapes() {
        // The conflict is rejected before the trace file is ever read, so
        // no file needs to exist here.
        let err = parse_open(&flags(&["--trace", "t.txt", "--rate", "80"]))
            .unwrap_err()
            .to_string();
        assert!(err.contains("conflicts with --trace"), "{err}");
        // A missing trace file is a readable error, not a panic.
        let err = parse_open(&flags(&["--trace", "/nonexistent/t.txt"]))
            .unwrap_err()
            .to_string();
        assert!(err.contains("cannot read trace"), "{err}");
    }

    #[test]
    fn shed_and_queue_flags_ride_along_with_open() {
        let cfg = parse_open(&flags(&["--open", "--rate", "60", "--shed", "--queue-cap", "32"]))
            .unwrap()
            .unwrap();
        assert!(cfg.shed);
        assert_eq!(cfg.queue_cap, Some(32));
    }

    #[test]
    fn placement_flag_selects_placers() {
        use super::parse_placement;
        use dnnscaler::coordinator::cluster::Placement;
        assert_eq!(parse_placement("rr").unwrap().name(), "rr");
        assert_eq!(parse_placement("bestfit").unwrap().name(), "bestfit");
        assert_eq!(
            parse_placement("interference-aware").unwrap().name(),
            "interference"
        );
        let err = parse_placement("magic").unwrap_err().to_string();
        assert!(err.contains("magic"), "{err}");
    }

    #[test]
    fn method_flag_selects_policies() {
        assert!(matches!(parse_method(&flags(&[])).unwrap(), PolicySpec::DnnScaler));
        assert!(matches!(
            parse_method(&flags(&["--method", "queue"])).unwrap(),
            PolicySpec::QueueAware
        ));
        assert!(matches!(
            parse_method(&flags(&["--method", "clipper"])).unwrap(),
            PolicySpec::Clipper
        ));
        assert!(matches!(
            parse_method(&flags(&["--method", "combined"])).unwrap(),
            PolicySpec::Combined
        ));
        let err = parse_method(&flags(&["--method", "magic"])).unwrap_err().to_string();
        assert!(err.contains("magic"), "{err}");
        assert!(err.contains("combined"), "{err}");
    }

    #[test]
    fn slo_class_list_parses_letters_and_full_names() {
        use super::parse_slo_classes;
        use dnnscaler::coordinator::SloClass;
        assert_eq!(
            parse_slo_classes("g,silver, b").unwrap(),
            vec![SloClass::Gold, SloClass::Silver, SloClass::BestEffort]
        );
        let err = parse_slo_classes("g,x").unwrap_err().to_string();
        assert!(err.contains("--slo-class"), "{err}");
        assert!(err.contains("\"x\""), "{err}");
    }

    #[test]
    fn positive_list_accepts_good_values() {
        assert_eq!(parse_positive_list("rates", "10").unwrap(), vec![10.0]);
        assert_eq!(
            parse_positive_list("rates", " 10, 20.5 ,0.25").unwrap(),
            vec![10.0, 20.5, 0.25]
        );
    }

    #[test]
    fn positive_list_rejects_zero_negative_nan_and_garbage() {
        // The regression this parser exists for: `--rates 0`, `--rates
        // -5`, and `--rates nan` used to flow straight into the Poisson
        // generator / partition planner.
        assert_eq!(
            parse_positive_list("rates", "0"),
            Err(ListParseError::NonPositive { flag: "rates", value: 0.0 })
        );
        assert_eq!(
            parse_positive_list("rates", "10,-5"),
            Err(ListParseError::NonPositive { flag: "rates", value: -5.0 })
        );
        assert!(matches!(
            parse_positive_list("reservations", "nan"),
            Err(ListParseError::NotFinite { flag: "reservations", .. })
        ));
        assert!(matches!(
            parse_positive_list("reservations", "inf"),
            Err(ListParseError::NotFinite { .. })
        ));
        assert!(matches!(
            parse_positive_list("rates", "10,abc"),
            Err(ListParseError::Unparseable { .. })
        ));
        assert!(matches!(
            parse_positive_list("rates", "10,,20"),
            Err(ListParseError::Unparseable { .. })
        ));
        // The error message names the flag and the offending value.
        let msg = parse_positive_list("rates", "-1").unwrap_err().to_string();
        assert!(msg.contains("--rates"), "{msg}");
        assert!(msg.contains("-1"), "{msg}");
    }

    #[test]
    fn unknown_flag_is_rejected_with_allowed_list() {
        // The regression the strict parser exists for: `--windos 10` used
        // to be silently ignored.
        let args: Vec<String> = ["--windos", "10"].iter().map(|s| s.to_string()).collect();
        let err = Flags::parse(&args, &["windows", "seed"]).unwrap_err();
        let msg = err.to_string();
        assert!(msg.contains("unknown flag --windos"), "{msg}");
        assert!(msg.contains("--windows"), "{msg}");
        assert!(msg.contains("--seed"), "{msg}");
    }

    #[test]
    fn known_flags_parse_with_values_and_booleans() {
        let args: Vec<String> =
            ["--windows", "10", "--trace"].iter().map(|s| s.to_string()).collect();
        let f = Flags::parse(&args, &["windows", "trace", "seed"]).unwrap();
        assert_eq!(f.num_or("windows", 0usize).unwrap(), 10);
        assert!(f.has("trace"));
        assert_eq!(f.num_or("seed", 7u64).unwrap(), 7);
    }

    #[test]
    fn non_flag_argument_is_rejected() {
        let args: Vec<String> = ["oops"].iter().map(|s| s.to_string()).collect();
        assert!(Flags::parse(&args, &["windows"]).is_err());
    }

    #[test]
    fn churn_flag_parses_launch_and_retire_events() {
        let f = Flags::parse(&[], &[]).unwrap();
        let churn = super::parse_churn(&f, "launch:3@2:r45, retire:1@5").unwrap();
        assert_eq!(churn.len(), 2);
        // Rate token must be rRATE; retire takes none; kinds are fixed;
        // launched jobs must exist in the paper workload.
        for bad in
            ["launch:3@2:x45", "retire:1@5:r3", "boop:1@5", "launch:99@0", "launch:3", "retire:a@b"]
        {
            assert!(super::parse_churn(&f, bad).is_err(), "{bad:?} should be rejected");
        }
    }

    #[test]
    fn faults_flag_parses_crash_degrade_and_repair_events() {
        let sched = super::parse_faults("crash:1@2, degrade:0@1:0.5:3, repair:1@4").unwrap();
        assert_eq!(sched.len(), 3);
        // Kinds are fixed; crash/repair take no extras; degrade wants
        // exactly FACTOR and WINDOWS; DEV@W is mandatory everywhere.
        for bad in [
            "crash:1",
            "crash:1@2:9",
            "repair:1@2:0.5",
            "degrade:0@1",
            "degrade:0@1:0.5",
            "degrade:0@1:x:3",
            "melt:1@2",
            "crash:a@b",
        ] {
            assert!(super::parse_faults(bad).is_err(), "{bad:?} should be rejected");
        }
    }
}
