//! `dnnscaler` — CLI for the DNNScaler reproduction.
//!
//! Subcommands map onto the paper's workflow:
//!
//! * `zoo` — list calibrated paper DNNs and exported AOT artifacts;
//! * `profile` — run the Profiler on one DNN (Table 5 rows);
//! * `job` — run one Table 4 job end-to-end (DNNScaler vs Clipper);
//! * `jobs` — run the full 30-job workload (Fig. 5 summary);
//! * `sweep` — throughput/latency vs BS or MTL (Fig. 1 curves);
//! * `serve` — real-mode serving of an AOT artifact over PJRT.
//!
//! Argument parsing is hand-rolled (this build is fully offline; see
//! Cargo.toml) — `--key value` flags after the subcommand.

use anyhow::{anyhow, bail, Result};

use dnnscaler::coordinator::job::{paper_job, JobSpec, PAPER_JOBS};
use dnnscaler::coordinator::runner::{JobRunner, RunConfig};
use dnnscaler::coordinator::{Method, Profiler};
use dnnscaler::device::real::RealDevice;
use dnnscaler::gpusim::{Dataset, GpuSim, PAPER_DNNS};
use dnnscaler::manifest::Manifest;
use dnnscaler::metrics::report::{f1, f2};
use dnnscaler::metrics::Table;

const USAGE: &str = "\
dnnscaler — Batching or Multi-Tenancy? (CS.DC 2023 reproduction)

USAGE: dnnscaler <COMMAND> [--flag value ...]

COMMANDS:
  zoo      [--artifacts DIR]
           List calibrated paper DNNs and exported AOT artifacts.
  profile  --dnn NAME [--dataset DS] [--seed N]
           Run the Profiler on one paper DNN (simulated P40).
  job      --id 1..30 [--windows N] [--seed N] [--trace]
           Run one Table 4 job: DNNScaler vs Clipper.
  jobs     [--windows N] [--seed N]
           Run the full 30-job workload (Fig. 5 summary).
  sweep    --dnn NAME [--dataset DS] [--knob bs|mtl]
           Throughput/latency sweep over one knob (Fig. 1 curves).
  serve    [--model M] [--slo MS] [--artifacts DIR] [--windows N]
           Serve a real AOT artifact over PJRT with DNNScaler.

Datasets: imagenet caltech sentiment140 imdb ledov dhf1k librispeech
";

/// Tiny `--key value` flag parser (flags without value become `true`).
struct Flags(Vec<(String, String)>);

impl Flags {
    fn parse(args: &[String]) -> Result<Flags> {
        let mut out = Vec::new();
        let mut i = 0;
        while i < args.len() {
            let a = &args[i];
            let key = a
                .strip_prefix("--")
                .ok_or_else(|| anyhow!("expected --flag, got {a:?}\n\n{USAGE}"))?;
            if i + 1 < args.len() && !args[i + 1].starts_with("--") {
                out.push((key.to_string(), args[i + 1].clone()));
                i += 2;
            } else {
                out.push((key.to_string(), "true".to_string()));
                i += 1;
            }
        }
        Ok(Flags(out))
    }

    fn get(&self, key: &str) -> Option<&str> {
        self.0.iter().find(|(k, _)| k == key).map(|(_, v)| v.as_str())
    }

    fn str_or(&self, key: &str, default: &str) -> String {
        self.get(key).unwrap_or(default).to_string()
    }

    fn num_or<T: std::str::FromStr>(&self, key: &str, default: T) -> Result<T> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|_| anyhow!("--{key}: cannot parse {v:?}")),
        }
    }

    fn has(&self, key: &str) -> bool {
        self.get(key).is_some()
    }
}

fn parse_dataset(s: &str) -> Result<Dataset> {
    Dataset::parse(s).ok_or_else(|| anyhow!("unknown dataset {s:?}"))
}

fn main() -> Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let Some(cmd) = args.first() else {
        print!("{USAGE}");
        return Ok(());
    };
    let flags = Flags::parse(&args[1..])?;
    match cmd.as_str() {
        "zoo" => cmd_zoo(&flags.str_or("artifacts", "artifacts")),
        "profile" => {
            let dnn = flags.get("dnn").ok_or_else(|| anyhow!("profile needs --dnn"))?;
            cmd_profile(dnn, &flags.str_or("dataset", "imagenet"), flags.num_or("seed", 42u64)?)
        }
        "job" => cmd_job(
            flags.num_or("id", 0u32).and_then(|id| {
                if id == 0 {
                    bail!("job needs --id 1..30")
                } else {
                    Ok(id)
                }
            })?,
            flags.num_or("windows", 60usize)?,
            flags.num_or("seed", 42u64)?,
            flags.has("trace"),
        ),
        "jobs" => cmd_jobs(flags.num_or("windows", 40usize)?, flags.num_or("seed", 42u64)?),
        "sweep" => {
            let dnn = flags.get("dnn").ok_or_else(|| anyhow!("sweep needs --dnn"))?;
            cmd_sweep(dnn, &flags.str_or("dataset", "imagenet"), &flags.str_or("knob", "bs"))
        }
        "serve" => cmd_serve(
            &flags.str_or("model", "mobv1-025"),
            flags.num_or("slo", 50.0f64)?,
            &flags.str_or("artifacts", "artifacts"),
            flags.num_or("windows", 20usize)?,
        ),
        "help" | "--help" | "-h" => {
            print!("{USAGE}");
            Ok(())
        }
        other => {
            bail!("unknown command {other:?}\n\n{USAGE}");
        }
    }
}

fn cmd_zoo(artifacts: &str) -> Result<()> {
    let mut t = Table::new(
        "Calibrated paper DNNs (gpusim)",
        &["dnn", "weights(MB)", "bsat", "r1", "prep(ms)", "kappa"],
    );
    for p in PAPER_DNNS {
        t.row(&[
            p.name.into(),
            f1(p.weight_mb),
            f1(p.bsat),
            f2(p.r1),
            f2(p.t_prep_ms),
            f2(p.kappa),
        ]);
    }
    print!("{}", t.render());

    match Manifest::load(artifacts) {
        Ok(m) => {
            let mut t = Table::new(
                "AOT artifacts (real mode)",
                &["model", "batch sizes", "params", "analogue"],
            );
            for model in m.models() {
                let sizes = m.batch_sizes(&model);
                let e = m.get(&model, sizes[0]).unwrap();
                t.row(&[
                    model.clone(),
                    format!("{sizes:?}"),
                    e.param_count.to_string(),
                    e.paper_analogue.clone(),
                ]);
            }
            print!("{}", t.render());
        }
        Err(e) => println!("(no artifacts: {e})"),
    }
    Ok(())
}

fn cmd_profile(dnn: &str, dataset: &str, seed: u64) -> Result<()> {
    let ds = parse_dataset(dataset)?;
    let mut sim = GpuSim::for_paper_dnn(dnn, ds, seed)
        .ok_or_else(|| anyhow!("unknown DNN {dnn:?} (see `dnnscaler zoo`)"))?;
    let out = Profiler::default().run(&mut sim).map_err(|e| anyhow!(e.to_string()))?;
    println!("DNN {dnn} on {}:", ds.name());
    println!("  base throughput  {:>9.2} inf/s (lat {:.2} ms)", out.thr_base, out.lat_base_ms);
    println!("  BS=32 throughput {:>9.2} inf/s -> TI_B  = {:>7.2}%", out.thr_batch, out.ti_b);
    println!("  MTL=8 throughput {:>9.2} inf/s -> TI_MT = {:>7.2}%", out.thr_mt, out.ti_mt);
    println!("  method: {:?} (profiling overhead {:.0} ms)", out.method, out.overhead_ms);
    Ok(())
}

fn run_job_pair(
    job: &JobSpec,
    windows: usize,
    seed: u64,
) -> Result<(dnnscaler::JobOutcome, dnnscaler::JobOutcome)> {
    let cfg = RunConfig::windows(windows, 20);
    let runner = JobRunner::new(cfg);
    let mut d1 = GpuSim::for_paper_dnn(job.dnn, job.dataset, seed)
        .ok_or_else(|| anyhow!("unknown DNN {:?}", job.dnn))?;
    let scaler = runner.run_dnnscaler(job, &mut d1).map_err(|e| anyhow!(e.to_string()))?;
    let mut d2 = GpuSim::for_paper_dnn(job.dnn, job.dataset, seed + 1).unwrap();
    let clipper = runner.run_clipper(job, &mut d2).map_err(|e| anyhow!(e.to_string()))?;
    Ok((scaler, clipper))
}

fn cmd_job(id: u32, windows: usize, seed: u64, trace: bool) -> Result<()> {
    let job = paper_job(id).ok_or_else(|| anyhow!("job id must be 1..=30"))?;
    let (scaler, clipper) = run_job_pair(job, windows, seed)?;
    println!(
        "Job {} ({} on {}, SLO {} ms): paper method {:?}",
        job.id,
        job.dnn,
        job.dataset.name(),
        job.slo_ms,
        job.paper_method
    );
    for o in [&scaler, &clipper] {
        println!(
            "  {:<10} thr {:>9.2} inf/s  p95 {:>8.2} ms  SLO-attain {:>5.1}%  power {:>6.1} W  knob bs={} mtl={}",
            o.controller,
            o.throughput,
            o.p95_ms,
            o.slo_attainment * 100.0,
            o.power_w,
            o.steady_bs,
            o.steady_mtl
        );
    }
    println!(
        "  speedup: {:.2}x (method chosen: {:?})",
        scaler.throughput / clipper.throughput,
        scaler.method.unwrap()
    );
    if trace {
        for r in &scaler.trace {
            println!(
                "    w{:03} bs={} mtl={} p95={:.2} slo={:.0} thr={:.1}",
                r.window, r.bs, r.mtl, r.p95_ms, r.slo_ms, r.throughput
            );
        }
    }
    Ok(())
}

fn cmd_jobs(windows: usize, seed: u64) -> Result<()> {
    let mut t = Table::new(
        "All 30 jobs: DNNScaler vs Clipper (Fig. 5)",
        &["job", "dnn", "method", "paper", "knob", "scaler thr", "clipper thr", "speedup", "attain%"],
    );
    let mut sum_gain = 0.0;
    let mut max_gain: (f64, u32) = (0.0, 0);
    let mut method_hits = 0;
    for job in PAPER_JOBS {
        let (scaler, clipper) = run_job_pair(job, windows, seed)?;
        let gain = scaler.throughput / clipper.throughput;
        sum_gain += gain;
        if gain > max_gain.0 {
            max_gain = (gain, job.id);
        }
        let method = scaler.method.unwrap();
        if method == job.paper_method {
            method_hits += 1;
        }
        let knob = match method {
            Method::Batching => format!("BS={}", scaler.steady_bs),
            Method::MultiTenancy => format!("MTL={}", scaler.steady_mtl),
        };
        t.row(&[
            job.id.to_string(),
            job.dnn.into(),
            method.short().into(),
            job.paper_method.short().into(),
            knob,
            f1(scaler.throughput),
            f1(clipper.throughput),
            f2(gain),
            f1(scaler.slo_attainment * 100.0),
        ]);
    }
    print!("{}", t.render());
    println!(
        "method agreement with Table 4: {}/30; mean speedup {:.2}x; max {:.2}x (job {})",
        method_hits,
        sum_gain / PAPER_JOBS.len() as f64,
        max_gain.0,
        max_gain.1
    );
    Ok(())
}

fn cmd_sweep(dnn: &str, dataset: &str, knob: &str) -> Result<()> {
    let ds = parse_dataset(dataset)?;
    let sim = GpuSim::for_paper_dnn(dnn, ds, 0).ok_or_else(|| anyhow!("unknown DNN {dnn:?}"))?;
    match knob {
        "bs" => {
            let mut t = Table::new(
                &format!("{dnn}: Batching sweep (Fig. 1a/1c)"),
                &["bs", "throughput", "latency(ms)", "power(W)", "sm util"],
            );
            for bs in [1u32, 2, 4, 8, 16, 32, 64, 128] {
                t.row(&[
                    bs.to_string(),
                    f1(sim.throughput(bs, 1)),
                    f2(sim.mean_batch_latency_ms(bs, 1)),
                    f1(sim.power_w(bs, 1)),
                    f2(sim.sm_utilization(bs, 1)),
                ]);
            }
            print!("{}", t.render());
        }
        "mtl" => {
            let mut t = Table::new(
                &format!("{dnn}: Multi-Tenancy sweep (Fig. 1b/1d)"),
                &["mtl", "throughput", "latency(ms)", "power(W)", "sm util"],
            );
            for n in 1..=10u32 {
                t.row(&[
                    n.to_string(),
                    f1(sim.throughput(1, n)),
                    f2(sim.mean_batch_latency_ms(1, n)),
                    f1(sim.power_w(1, n)),
                    f2(sim.sm_utilization(1, n)),
                ]);
            }
            print!("{}", t.render());
        }
        other => return Err(anyhow!("knob must be `bs` or `mtl`, got {other:?}")),
    }
    Ok(())
}

fn cmd_serve(model: &str, slo: f64, artifacts: &str, windows: usize) -> Result<()> {
    let mut dev = RealDevice::open(artifacts, model)?;
    println!("loaded {model} (max BS {})", dev.max_batch_size());
    let job = JobSpec {
        id: 0,
        dnn: Box::leak(model.to_string().into_boxed_str()),
        dataset: Dataset::Synthetic,
        slo_ms: slo,
        paper_method: Method::Batching,
        paper_steady: dnnscaler::coordinator::job::SteadyKnob::Bs(1),
    };
    let max_bs = dev.max_batch_size();
    let cfg = RunConfig {
        windows,
        rounds_per_window: 10,
        max_bs,
        probe_bs: 8.min(max_bs),
        probe_mtl: 4,
        ..Default::default()
    };
    let out = JobRunner::new(cfg)
        .run_dnnscaler(&job, &mut dev)
        .map_err(|e| anyhow!(e.to_string()))?;
    println!(
        "served: method {:?}, steady bs={} mtl={}, throughput {:.1} inf/s, p95 {:.2} ms, SLO attainment {:.1}%",
        out.method.unwrap(),
        out.steady_bs,
        out.steady_mtl,
        out.throughput,
        out.p95_ms,
        out.slo_attainment * 100.0
    );
    for (bs, ms) in dev.pool().compile_report() {
        println!("  compiled bs={bs} in {ms:.0} ms (once)");
    }
    Ok(())
}
