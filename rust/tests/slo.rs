//! SLO-class serving acceptance tests (PR 10).
//!
//! The headline scenario replays the shipped Azure-Functions-style
//! arrival trace — time-compressed into sustained overload — into a
//! four-member MPS fleet with mixed service classes, and requires the
//! paper's combined Batching + Multi-Tenancy search to strictly beat the
//! single-knob baselines (QueuePolicy, DNNScaler, Clipper) on
//! gold-class goodput. Around it: the class model's degeneracy
//! contracts (all-gold == unclassed byte-for-byte, unclassed snapshots
//! carry no `slo` key), bounded best-effort starvation, per-class
//! conservation through `ClusterOutcome::audit`, and thread-count
//! determinism for classed clusters.

use dnnscaler::coordinator::job::paper_job;
use dnnscaler::coordinator::session::{ConfigError, PolicySpec};
use dnnscaler::coordinator::snapshot::{
    cluster_outcome_to_json, fleet_outcome_to_json, job_outcome_to_json, render,
};
use dnnscaler::coordinator::{AuditError, Cluster, Fleet, FleetOutcome, SloClass};
use dnnscaler::gpusim::{PartitionMode, TESLA_P40, TESLA_T4};
use dnnscaler::workload::ArrivalPattern;

/// The shipped Azure-Functions-style trace (see `data/README` header in
/// the file itself), time-compressed by `compress` so its ~9 req/s mean
/// becomes `9 * compress` req/s — the overload driver for every test
/// here. Parsed by hand so the compression stays explicit in the test.
fn azure_overload_trace(compress: f64) -> ArrivalPattern {
    let path =
        std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("../data/azure_functions_sample.txt");
    let text = std::fs::read_to_string(&path)
        .unwrap_or_else(|e| panic!("{}: {e}", path.display()));
    let ts: Vec<f64> = text
        .lines()
        .map(str::trim)
        .filter(|l| !l.is_empty() && !l.starts_with('#'))
        .map(|l| l.parse::<f64>().expect("trace lines are f64 seconds") / compress)
        .collect();
    assert!(ts.len() > 400, "trace is suspiciously small: {}", ts.len());
    ArrivalPattern::trace(ts).expect("compressed trace stays sorted and positive")
}

/// Four paper models sharing one MPS-partitioned P40 under the
/// compressed Azure trace, with per-member policies built by `spec`
/// (PolicySpec is not Clone, hence the closure) and an optional class
/// list [gold, silver, best-effort, best-effort].
fn azure_mps_fleet(
    spec: impl Fn() -> PolicySpec<'static>,
    classes: Option<&[SloClass]>,
) -> FleetOutcome {
    let trace = azure_overload_trace(20.0); // ~180 req/s per member
    let mut b = Fleet::builder()
        .gpu(TESLA_P40)
        .windows(8)
        .rounds_per_window(20)
        .seed(71)
        .partition_mode(PartitionMode::Mps);
    for id in [1u32, 4, 5, 7] {
        let job = paper_job(id).unwrap();
        b = b
            .job_with_arrivals(job, spec(), trace.clone())
            .batch_timeout_ms(4.0)
            .queue_capacity(256)
            .shed_deadline(true);
    }
    if let Some(cs) = classes {
        b = b.slo_classes(cs);
    }
    b.build().unwrap().run().unwrap()
}

const MIXED: [SloClass; 4] =
    [SloClass::Gold, SloClass::Silver, SloClass::BestEffort, SloClass::BestEffort];

// ---------------------------------------------------------------------------
// Acceptance: combined search beats every single-knob baseline on gold
// ---------------------------------------------------------------------------

#[test]
fn combined_policy_beats_single_knob_baselines_on_gold_goodput() {
    let combined = azure_mps_fleet(|| PolicySpec::Combined, Some(&MIXED));
    let queue = azure_mps_fleet(|| PolicySpec::QueueAware, Some(&MIXED));
    let dnnscaler = azure_mps_fleet(|| PolicySpec::DnnScaler, Some(&MIXED));
    let clipper = azure_mps_fleet(|| PolicySpec::Clipper, Some(&MIXED));

    // The trace must actually overload the fleet: without shedding
    // pressure, every policy serves everything and the comparison is
    // vacuous.
    let total_shed: u64 = combined.members.iter().map(|m| m.dropped_deadline).sum();
    assert!(total_shed > 0, "compressed Azure trace must drive the fleet into shedding");

    let gold = |o: &FleetOutcome| {
        o.slo.as_ref().expect("classed run must report slo").class(SloClass::Gold).goodput
    };
    let (g_combined, g_queue, g_dnn, g_clipper) =
        (gold(&combined), gold(&queue), gold(&dnnscaler), gold(&clipper));
    assert!(
        g_combined > g_queue,
        "combined gold goodput {g_combined:.2} must beat queue-aware {g_queue:.2}"
    );
    assert!(
        g_combined > g_dnn,
        "combined gold goodput {g_combined:.2} must beat dnnscaler {g_dnn:.2}"
    );
    assert!(
        g_combined > g_clipper,
        "combined gold goodput {g_combined:.2} must beat clipper {g_clipper:.2}"
    );

    // The report is internally consistent: per-class goodput sums to the
    // per-member goodput of that class's members.
    let slo = combined.slo.as_ref().unwrap();
    for c in SloClass::ALL {
        let member_sum: f64 = combined
            .members
            .iter()
            .zip(&MIXED)
            .filter(|&(_, mc)| *mc == c)
            .map(|(m, _)| m.goodput)
            .sum();
        assert!(
            (slo.class(c).goodput - member_sum).abs() < 1e-9,
            "{} goodput must equal its members' sum",
            c.name()
        );
    }
}

// ---------------------------------------------------------------------------
// Degeneracy contracts
// ---------------------------------------------------------------------------

#[test]
fn all_gold_pool_degenerates_to_the_unclassed_run_byte_for_byte() {
    // Gold's shed scale is 1.0 and uniform weights restrict nothing, so
    // an all-gold pool must reproduce the unclassed run exactly — per
    // member, byte for byte — and differ in the snapshot only by the
    // `slo` key.
    let plain = azure_mps_fleet(|| PolicySpec::Combined, None);
    let gold = azure_mps_fleet(|| PolicySpec::Combined, Some(&[SloClass::Gold]));

    assert!(plain.slo.is_none(), "unclassed run must not report slo");
    assert!(gold.slo.is_some(), "all-gold run must report slo");
    for (p, g) in plain.members.iter().zip(&gold.members) {
        assert_eq!(
            render(&job_outcome_to_json(p)),
            render(&job_outcome_to_json(g)),
            "job {} drifted under an all-gold class list",
            p.job_id
        );
    }

    // Satellite regression pin: the unclassed fleet snapshot carries no
    // `slo` key anywhere, so every pre-PR-10 fixture stays byte-valid.
    let bytes = render(&fleet_outcome_to_json(&plain));
    assert!(!bytes.contains("\"slo\""), "unclassed snapshot must omit the slo key");
    let gold_bytes = render(&fleet_outcome_to_json(&gold));
    assert!(gold_bytes.contains("\"slo\""), "classed snapshot must carry the slo key");
}

#[test]
fn best_effort_starvation_is_bounded_under_overload() {
    // Best-effort sheds earliest (scale 0.5) and shrinks first under
    // admission pressure, but it is never starved outright: its members
    // still serve deadline-met work.
    let out = azure_mps_fleet(|| PolicySpec::Combined, Some(&MIXED));
    let be = out.slo.as_ref().unwrap().class(SloClass::BestEffort);
    assert_eq!(be.members, 2);
    assert!(
        be.goodput > 0.0,
        "best-effort goodput floor violated: {:.3} (shed {})",
        be.goodput,
        be.shed
    );
    // And the class ordering holds where it must: best-effort sheds at
    // least as much per member as gold (tighter effective deadline).
    let gold = out.slo.as_ref().unwrap().class(SloClass::Gold);
    assert!(
        be.shed as f64 / be.members as f64 >= gold.shed as f64 / gold.members as f64,
        "best-effort must not shed less per member than gold (be {} gold {})",
        be.shed,
        gold.shed
    );
}

// ---------------------------------------------------------------------------
// Typed knob validation (satellite 1)
// ---------------------------------------------------------------------------

#[test]
fn deadline_knob_validation_is_typed() {
    let job = paper_job(1).unwrap();
    // deadline_ms on a closed-loop member: open-loop-only knob.
    assert_eq!(
        Fleet::builder().job(job, PolicySpec::Clipper).deadline_ms(40.0).build().err(),
        Some(ConfigError::KnobRequiresOpenLoop { knob: "deadline_ms" })
    );
    // Open loop but shedding off: the deadline would be a silent no-op.
    assert_eq!(
        Fleet::builder()
            .job_with_arrivals(job, PolicySpec::Clipper, ArrivalPattern::poisson(30.0))
            .deadline_ms(40.0)
            .build()
            .err(),
        Some(ConfigError::DeadlineRequiresShed)
    );
    // Non-finite / non-positive deadlines are refused up front.
    for bad in [0.0, -5.0, f64::NAN, f64::INFINITY] {
        let err = Fleet::builder()
            .job_with_arrivals(job, PolicySpec::Clipper, ArrivalPattern::poisson(30.0))
            .shed_deadline(true)
            .deadline_ms(bad)
            .build()
            .err();
        assert!(
            matches!(err, Some(ConfigError::BadDeadline { .. })),
            "deadline {bad} must be a typed BadDeadline, got {err:?}"
        );
    }
    // A valid explicit deadline with shedding on builds and runs.
    let out = Fleet::builder()
        .windows(4)
        .rounds_per_window(8)
        .seed(9)
        .job_with_arrivals(job, PolicySpec::Static { bs: 2, mtl: 1 }, ArrivalPattern::poisson(60.0))
        .shed_deadline(true)
        .deadline_ms(40.0)
        .build()
        .unwrap()
        .run()
        .unwrap();
    assert_eq!(out.members.len(), 1);
}

// ---------------------------------------------------------------------------
// Cluster path: conservation audit + thread determinism
// ---------------------------------------------------------------------------

fn classed_cluster(threads: usize) -> dnnscaler::coordinator::ClusterOutcome {
    let mut b = Cluster::builder()
        .windows(6)
        .rounds_per_window(10)
        .seed(23)
        .threads(threads)
        .device(TESLA_P40)
        .device(TESLA_T4);
    for id in [1u32, 5, 7] {
        let job = paper_job(id).unwrap();
        b = b
            .job_with_arrivals(job, PolicySpec::Combined, ArrivalPattern::poisson(45.0))
            .shed_deadline(true);
    }
    b.slo_classes(&[SloClass::Gold, SloClass::Silver, SloClass::BestEffort])
        .build()
        .unwrap()
        .run()
        .unwrap()
}

#[test]
fn classed_cluster_audits_per_class_and_rejects_forgeries() {
    let mut out = classed_cluster(1);
    assert!(out.audit().is_ok(), "honest classed run must audit clean: {:?}", out.audit());
    let slo = out.slo.clone().expect("classed cluster must report slo");
    for c in SloClass::ALL {
        assert_eq!(slo.class(c).members, 1, "{} membership", c.name());
    }
    // Forged cluster-level gold goodput: the per-member recount refuses.
    if let Some(r) = out.slo.as_mut() {
        r.per_class[0].goodput += 1.0;
    }
    assert!(
        matches!(
            out.audit(),
            Err(AuditError::ClassAccounting { class: "gold", field: "goodput", .. })
        ),
        "forged gold goodput must fail the class audit: {:?}",
        out.audit()
    );
}

#[test]
fn classed_cluster_is_byte_identical_across_thread_counts() {
    let serial = render(&cluster_outcome_to_json(&classed_cluster(1)));
    for threads in [2usize, 8] {
        let sharded = render(&cluster_outcome_to_json(&classed_cluster(threads)));
        assert_eq!(
            serial, sharded,
            "classed cluster must be byte-identical at --threads {threads}"
        );
    }
}
