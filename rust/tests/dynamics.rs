//! Warehouse-dynamics integration tests: churn, live migration, and
//! price-aware autoscaling through the public `Cluster` API, including
//! the full-day diurnal-trace economics check and the static-path
//! byte-identity guarantee.

use dnnscaler::coordinator::cluster::{ClusterOutcome, DeviceDesc, PlacementJob};
use dnnscaler::coordinator::dynamics::{
    Autoscaler, ChurnSchedule, PlacementPolicy, PoolObservation, ScaleAction, ThresholdAutoscaler,
};
use dnnscaler::coordinator::job::paper_job;
use dnnscaler::coordinator::session::{ConfigError, PolicySpec};
use dnnscaler::coordinator::snapshot::{cluster_outcome_to_json, render};
use dnnscaler::coordinator::{Cluster, WindowObservation};
use dnnscaler::gpusim::TESLA_P40;
use dnnscaler::workload::ArrivalPattern;

fn snapshot(out: &ClusterOutcome) -> String {
    render(&cluster_outcome_to_json(out))
}

/// A dynamics-free build must keep producing the exact bytes the static
/// path always produced — an empty churn schedule (and price metadata)
/// must not flip the run onto the dynamic path.
#[test]
fn empty_dynamics_stays_byte_identical_to_static() {
    let run = |decorate: bool| {
        let mut b = Cluster::builder()
            .device(TESLA_P40)
            .device(TESLA_P40)
            .job_with_arrivals(
                paper_job(1).unwrap(),
                PolicySpec::Static { bs: 2, mtl: 1 },
                ArrivalPattern::poisson(40.0),
            )
            .job_with_arrivals(
                paper_job(5).unwrap(),
                PolicySpec::Static { bs: 1, mtl: 2 },
                ArrivalPattern::poisson(30.0),
            )
            .windows(6)
            .rounds_per_window(12)
            .seed(11);
        if decorate {
            b = b.churn(ChurnSchedule::new()).prices(&[0.9]);
        }
        b.build().unwrap().run().unwrap()
    };
    let plain = run(false);
    let decorated = run(true);
    assert!(plain.dynamics.is_none());
    assert!(decorated.dynamics.is_none(), "empty churn must not switch paths");
    assert_eq!(snapshot(&plain), snapshot(&decorated));
}

/// Same seed + same churn/migration/autoscaling schedule => the same
/// snapshot, byte for byte.
#[test]
fn dynamic_runs_are_deterministic() {
    let run = || {
        let churn = ChurnSchedule::new()
            .launch(
                2,
                paper_job(4).unwrap(),
                PolicySpec::Static { bs: 1, mtl: 1 },
                ArrivalPattern::poisson(25.0),
            )
            .retire(6, 4);
        Cluster::builder()
            .device(TESLA_P40)
            .device(TESLA_P40)
            .job_with_arrivals(
                paper_job(1).unwrap(),
                PolicySpec::Static { bs: 2, mtl: 1 },
                ArrivalPattern::poisson(40.0),
            )
            .job_with_arrivals(
                paper_job(5).unwrap(),
                PolicySpec::Static { bs: 1, mtl: 2 },
                ArrivalPattern::poisson(30.0),
            )
            .churn(churn)
            .autoscaler(ThresholdAutoscaler::new(1, 3))
            .windows(8)
            .rounds_per_window(12)
            .seed(21)
            .build()
            .unwrap()
            .run()
            .unwrap()
    };
    let a = run();
    let b = run();
    let dy = a.dynamics.as_ref().expect("churn run must report dynamics");
    assert_eq!(dy.launches, 1);
    assert_eq!(dy.retires, 1);
    assert_eq!(a.dynamics, b.dynamics);
    assert_eq!(snapshot(&a), snapshot(&b));
}

/// A policy that swaps the first two jobs' devices exactly once.
struct SwapOnce {
    fired: bool,
}

impl PlacementPolicy for SwapOnce {
    fn name(&self) -> &'static str {
        "swap-once"
    }

    fn replace(
        &mut self,
        jobs: &[PlacementJob],
        devices: &[DeviceDesc],
        current: &[usize],
        _obs: &[WindowObservation],
    ) -> Option<Vec<usize>> {
        if self.fired || jobs.len() < 2 || devices.len() < 2 || current[0] == current[1] {
            return None;
        }
        self.fired = true;
        let mut v = current.to_vec();
        v.swap(0, 1);
        Some(v)
    }
}

/// A policy that always proposes an out-of-range device: every proposal
/// must be rejected, and nothing may ever move.
struct Bogus;

impl PlacementPolicy for Bogus {
    fn name(&self) -> &'static str {
        "bogus"
    }

    fn replace(
        &mut self,
        jobs: &[PlacementJob],
        _devices: &[DeviceDesc],
        _current: &[usize],
        _obs: &[WindowObservation],
    ) -> Option<Vec<usize>> {
        Some(vec![99; jobs.len()])
    }
}

fn two_job_cluster(policy: impl PlacementPolicy + 'static) -> ClusterOutcome {
    Cluster::builder()
        .device(TESLA_P40)
        .device(TESLA_P40)
        .job_with_arrivals(
            paper_job(1).unwrap(),
            PolicySpec::Static { bs: 2, mtl: 1 },
            ArrivalPattern::poisson(40.0),
        )
        .job_with_arrivals(
            paper_job(5).unwrap(),
            PolicySpec::Static { bs: 1, mtl: 2 },
            ArrivalPattern::poisson(30.0),
        )
        .placement_policy(policy)
        .windows(6)
        .rounds_per_window(12)
        .seed(13)
        .build()
        .unwrap()
        .run()
        .unwrap()
}

/// Each accepted move is counted and charged its model-load stall; the
/// jobs keep serving on their new devices.
#[test]
fn migrations_are_counted_and_charged() {
    let out = two_job_cluster(SwapOnce { fired: false });
    let dy = out.dynamics.as_ref().unwrap();
    assert_eq!(dy.migrations, 2, "one swap = two job moves");
    assert_eq!(dy.rejected_proposals, 0);
    assert!(
        dy.migration_stall_ms >= 2.0 * 2000.0,
        "each move must pay at least the fixed model-load cost (got {} ms)",
        dy.migration_stall_ms
    );
    // The swap really happened: final assignment differs from round-robin.
    assert_eq!(out.assignment, vec![1, 0]);
    assert!(out.total_throughput > 0.0);
    assert_eq!(out.audit(), Ok(()));
}

/// Malformed proposals are rejected wholesale — counted, never applied,
/// never charged.
#[test]
fn malformed_proposals_are_rejected_not_applied() {
    let out = two_job_cluster(Bogus);
    let dy = out.dynamics.as_ref().unwrap();
    assert!(dy.rejected_proposals > 0);
    assert_eq!(dy.migrations, 0);
    assert_eq!(dy.migration_stall_ms, 0.0);
    assert_eq!(out.assignment, vec![0, 1], "round-robin assignment must survive");
}

/// Property over seeds: the pool never leaves `[min, max]`, every
/// window's accounting audits clean, and shrinking never loses a job
/// (everything keeps serving).
#[test]
fn autoscaled_pool_respects_bounds_across_seeds() {
    for seed in [1u64, 7, 23, 42, 97] {
        let out = Cluster::builder()
            .device(TESLA_P40)
            .device(TESLA_P40)
            .device(TESLA_P40)
            .job_with_arrivals(
                paper_job(1).unwrap(),
                PolicySpec::Static { bs: 2, mtl: 1 },
                ArrivalPattern::poisson(35.0),
            )
            .job_with_arrivals(
                paper_job(5).unwrap(),
                PolicySpec::Static { bs: 1, mtl: 2 },
                ArrivalPattern::poisson(25.0),
            )
            .autoscaler(ThresholdAutoscaler::new(1, 4))
            .windows(10)
            .rounds_per_window(10)
            .seed(seed)
            .build()
            .unwrap()
            .run()
            .unwrap();
        let dy = out.dynamics.as_ref().unwrap();
        assert_eq!(dy.pool_trace.len(), 10, "seed {seed}");
        for (w, &n) in dy.pool_trace.iter().enumerate() {
            assert!((1..=4).contains(&n), "seed {seed}, window {w}: pool size {n}");
        }
        assert_eq!(out.audit(), Ok(()), "seed {seed}");
        // Both jobs must finish with real serving history whatever the
        // pool did.
        let served: usize = out.devices.iter().map(|d| d.fleet.members.len()).sum();
        assert_eq!(served, 2, "seed {seed}");
        assert!(out.total_throughput > 0.0, "seed {seed}");
        assert!(dy.device_hours > 0.0 && dy.cost_usd > 0.0, "seed {seed}");
    }
}

/// An autoscaler that never acts: a fixed pool with the same billing
/// machinery, the baseline the elastic pool must beat.
struct FixedPool;

impl Autoscaler for FixedPool {
    fn name(&self) -> &'static str {
        "fixed"
    }

    fn scale(&mut self, _obs: &PoolObservation<'_>) -> ScaleAction {
        ScaleAction::Hold
    }
}

/// Write a compressed full-day diurnal arrival trace (rate swinging
/// sinusoidally between ~2 and ~30 req/s over `day_s` virtual seconds)
/// and return its path.
fn write_diurnal_trace(day_s: f64) -> std::path::PathBuf {
    let path = std::env::temp_dir().join(format!("dnnscaler_diurnal_{day_s:.0}.trace"));
    let mut body = String::from("# compressed diurnal day: rate = 16 + 14*sin(...)\n");
    let mut t = 0.0f64;
    while t < day_s {
        let phase = 2.0 * std::f64::consts::PI * t / day_s - std::f64::consts::FRAC_PI_2;
        let rate = 16.0 + 14.0 * phase.sin();
        t += 1.0 / rate;
        body.push_str(&format!("{t:.6}\n"));
    }
    std::fs::write(&path, body).unwrap();
    path
}

/// The acceptance scenario: a full-day diurnal trace through a 3-device
/// cluster with churn. The threshold autoscaler must strictly beat the
/// fixed 3-device pool on cost per goodput — elasticity is the whole
/// point of the subsystem.
#[test]
fn diurnal_autoscaling_beats_fixed_pool_on_cost_per_goodput() {
    let trace = write_diurnal_trace(240.0);
    // The trace file also exercises the streaming reader end to end:
    // arrivals feed the cluster chunk-by-chunk from disk.
    let pattern = ArrivalPattern::from_trace_file(&trace).unwrap();
    assert!(matches!(pattern, ArrivalPattern::Streamed(_)));

    let run = |elastic: bool| {
        let churn = ChurnSchedule::new()
            .launch(
                3,
                paper_job(4).unwrap(),
                PolicySpec::Static { bs: 1, mtl: 1 },
                ArrivalPattern::poisson(15.0),
            )
            .retire(9, 4);
        let mut b = Cluster::builder()
            .device(TESLA_P40)
            .device(TESLA_P40)
            .device(TESLA_P40)
            .job_with_arrivals(
                paper_job(1).unwrap(),
                PolicySpec::Static { bs: 2, mtl: 1 },
                pattern.clone(),
            )
            .churn(churn)
            .windows(12)
            .rounds_per_window(20)
            .seed(7);
        b = if elastic {
            b.autoscaler(ThresholdAutoscaler::new(1, 3))
        } else {
            b.autoscaler(FixedPool)
        };
        b.build().unwrap().run().unwrap()
    };

    let fixed = run(false);
    let elastic = run(true);
    let fixed_dy = fixed.dynamics.as_ref().unwrap();
    let elastic_dy = elastic.dynamics.as_ref().unwrap();

    assert!(fixed_dy.pool_trace.iter().all(|&n| n == 3), "baseline must stay at 3");
    assert!(
        elastic_dy.pool_trace.iter().any(|&n| n < 3),
        "elastic pool never shrank: {:?}",
        elastic_dy.pool_trace
    );
    assert!(elastic_dy.cost_usd < fixed_dy.cost_usd);

    let fixed_cpg = fixed_dy.cost_per_goodput.expect("baseline goodput");
    let elastic_cpg = elastic_dy.cost_per_goodput.expect("elastic goodput");
    assert!(
        elastic_cpg < fixed_cpg,
        "autoscaling must strictly beat the fixed pool: {elastic_cpg:.6} vs {fixed_cpg:.6} $/goodput"
    );
    assert_eq!(fixed.audit(), Ok(()));
    assert_eq!(elastic.audit(), Ok(()));
}

// ---- Dynamics edge cases (ISSUE 8 satellites) ------------------------

/// Retiring a job that is never live — or retiring the same job twice —
/// is a typed `ConfigError::BadChurn` from `ClusterBuilder::build()`,
/// not a runtime surprise.
#[test]
fn retires_of_unknown_or_already_retired_jobs_fail_at_build() {
    let job = paper_job(1).unwrap();
    let base = || {
        Cluster::builder().device(TESLA_P40).job_with_arrivals(
            job,
            PolicySpec::Static { bs: 1, mtl: 1 },
            ArrivalPattern::poisson(20.0),
        )
    };

    // Job 999 never exists in this run.
    let err = base()
        .churn(ChurnSchedule::new().retire(1, 999))
        .windows(4)
        .build()
        .err()
        .expect("retiring an unknown job must fail at build");
    assert!(matches!(err, ConfigError::BadChurn { .. }), "got {err:?}");

    // The second retire acts on a job the first already removed.
    let err = base()
        .churn(ChurnSchedule::new().retire(1, job.id).retire(2, job.id))
        .windows(4)
        .build()
        .err()
        .expect("double retire must fail at build");
    assert!(matches!(err, ConfigError::BadChurn { .. }), "got {err:?}");
}

/// Scales down exactly once, on its first consultation, then holds.
struct ShrinkOnce {
    done: bool,
}

impl Autoscaler for ShrinkOnce {
    fn name(&self) -> &'static str {
        "shrink-once"
    }

    fn scale(&mut self, _obs: &PoolObservation<'_>) -> ScaleAction {
        if self.done {
            ScaleAction::Hold
        } else {
            self.done = true;
            ScaleAction::Shrink
        }
    }
}

/// Demands a scale-down at every window boundary, unconditionally.
struct ShrinkAlways;

impl Autoscaler for ShrinkAlways {
    fn name(&self) -> &'static str {
        "shrink-always"
    }

    fn scale(&mut self, _obs: &PoolObservation<'_>) -> ScaleAction {
        ScaleAction::Shrink
    }
}

/// A churned launch arriving after the pool has shrunk must land on a
/// still-active device — never on the parked card — and serve to the
/// end of the run with clean accounting.
#[test]
fn launch_lands_on_an_active_device_while_the_pool_shrinks() {
    let out = Cluster::builder()
        .device(TESLA_P40)
        .device(TESLA_P40)
        .device(TESLA_P40)
        .job_with_arrivals(
            paper_job(1).unwrap(),
            PolicySpec::Static { bs: 2, mtl: 1 },
            ArrivalPattern::poisson(30.0),
        )
        .churn(ChurnSchedule::new().launch(
            2,
            paper_job(4).unwrap(),
            PolicySpec::Static { bs: 1, mtl: 1 },
            ArrivalPattern::poisson(20.0),
        ))
        .autoscaler(ShrinkOnce { done: false })
        .windows(6)
        .rounds_per_window(10)
        .seed(17)
        .build()
        .unwrap()
        .run()
        .unwrap();
    let dy = out.dynamics.as_ref().unwrap();
    assert_eq!(dy.scale_downs, 1, "the empty card must be parked at window 0");
    assert!(dy.pool_trace.iter().all(|&n| n == 2), "pool {:?}", dy.pool_trace);
    assert_eq!(dy.launches, 1, "the launch must be placed on a survivor");
    assert_eq!(dy.failed_launches, 0);
    // Both the initial job and the churned one finish with outcomes.
    let served: usize = out.devices.iter().map(|d| d.fleet.members.len()).sum();
    assert_eq!(served, 2);
    assert!(out.total_throughput > 0.0);
    assert_eq!(out.audit(), Ok(()));
}

/// When every device is occupied and no survivor could hold an
/// evacuated model, the shrink is refused every single window: the pool
/// never changes size and nothing migrates.
#[test]
fn shrink_is_refused_when_every_survivor_is_full() {
    use dnnscaler::gpusim::{GpuSim, GpuSpec};

    // Size each card so ONE inc-v4 footprint fits with < one footprint
    // of headroom: evacuating either device's job can never fit in the
    // other's free memory.
    let job = paper_job(3).unwrap();
    let footprint = GpuSim::for_paper_dnn(job.dnn, job.dataset, 0).unwrap().mem_demand_mb(1, 1);
    let gpu = GpuSpec { mem_mb: footprint * 1.8, ..TESLA_P40 };

    let out = Cluster::builder()
        .device(gpu.clone())
        .device(gpu)
        .job_with_arrivals(
            job,
            PolicySpec::Static { bs: 1, mtl: 1 },
            ArrivalPattern::poisson(15.0),
        )
        .job_with_arrivals(
            job,
            PolicySpec::Static { bs: 1, mtl: 1 },
            ArrivalPattern::poisson(15.0),
        )
        .autoscaler(ShrinkAlways)
        .windows(6)
        .rounds_per_window(10)
        .seed(19)
        .build()
        .unwrap()
        .run()
        .unwrap();
    let dy = out.dynamics.as_ref().unwrap();
    assert_eq!(dy.scale_downs, 0, "no survivor can hold the evacuated footprint");
    assert!(dy.pool_trace.iter().all(|&n| n == 2), "pool {:?}", dy.pool_trace);
    assert_eq!(dy.migrations, 0, "a refused shrink must not half-move jobs");
    assert_eq!(dy.migration_stall_ms, 0.0);
    assert_eq!(out.assignment, vec![0, 1]);
    assert_eq!(out.audit(), Ok(()));
}

/// Once the pool has shrunk from three cards to two, proposes moving
/// every job onto active-slice index 2 — exactly the retired card's old
/// slot, now out of range.
struct ChaseRetired;

impl PlacementPolicy for ChaseRetired {
    fn name(&self) -> &'static str {
        "chase-retired"
    }

    fn replace(
        &mut self,
        jobs: &[PlacementJob],
        devices: &[DeviceDesc],
        _current: &[usize],
        _obs: &[WindowObservation],
    ) -> Option<Vec<usize>> {
        if devices.len() >= 3 {
            return None;
        }
        Some(vec![2; jobs.len()])
    }
}

/// A migration proposal targeting a retired (powered-off) device is
/// validated against the ACTIVE slice of the pool: rejected wholesale,
/// counted, and nothing moves.
#[test]
fn proposals_targeting_a_retired_device_are_rejected() {
    let out = Cluster::builder()
        .device(TESLA_P40)
        .device(TESLA_P40)
        .device(TESLA_P40)
        .job_with_arrivals(
            paper_job(1).unwrap(),
            PolicySpec::Static { bs: 2, mtl: 1 },
            ArrivalPattern::poisson(30.0),
        )
        .placement_policy(ChaseRetired)
        .autoscaler(ShrinkOnce { done: false })
        .windows(6)
        .rounds_per_window(10)
        .seed(23)
        .build()
        .unwrap()
        .run()
        .unwrap();
    let dy = out.dynamics.as_ref().unwrap();
    assert_eq!(dy.scale_downs, 1, "the pool must actually shrink first");
    assert!(dy.rejected_proposals >= 1, "the stale-index proposal must be rejected");
    assert_eq!(dy.migrations, 0);
    assert_eq!(dy.migration_stall_ms, 0.0);
    assert_eq!(out.assignment, vec![0], "the job must stay where it was placed");
    assert_eq!(out.audit(), Ok(()));
}

// ---- Deferred launches (ISSUE 9 satellite) ---------------------------

/// Regression: a churned launch that fails placement because the pool is
/// momentarily full used to be dropped forever. It must instead wait in
/// the pending queue and place once a retire frees the memory.
#[test]
fn launch_that_finds_no_room_waits_and_places_after_a_retire() {
    use dnnscaler::gpusim::{GpuSim, GpuSpec};

    // One card sized for a single inc-v4 footprint: the window-1 launch
    // of a second copy cannot fit until the first retires at window 2.
    let job = paper_job(3).unwrap();
    let footprint = GpuSim::for_paper_dnn(job.dnn, job.dataset, 0).unwrap().mem_demand_mb(1, 1);
    let gpu = GpuSpec { mem_mb: footprint * 1.8, ..TESLA_P40 };

    let out = Cluster::builder()
        .device(gpu)
        .job_with_arrivals(
            job,
            PolicySpec::Static { bs: 1, mtl: 1 },
            ArrivalPattern::poisson(15.0),
        )
        .churn(
            ChurnSchedule::new()
                .launch(
                    1,
                    job,
                    PolicySpec::Static { bs: 1, mtl: 1 },
                    ArrivalPattern::poisson(15.0),
                )
                .retire(2, job.id),
        )
        .windows(6)
        .rounds_per_window(10)
        .seed(31)
        .build()
        .unwrap()
        .run()
        .unwrap();
    let dy = out.dynamics.as_ref().unwrap();
    assert_eq!(dy.deferred_launches, 1, "the full pool must defer, not drop");
    assert_eq!(dy.failed_launches, 0, "a deferred launch is not a failed one");
    assert_eq!(dy.launches, 1, "the retry must place it once memory frees");
    assert_eq!(dy.retires, 1);
    let served: usize = out.devices.iter().map(|d| d.fleet.members.len()).sum();
    assert_eq!(served, 2, "both the retiree and the deferred job finish with outcomes");
    assert_eq!(out.audit(), Ok(()));
    // Deferral is a dynamics fact, not a fault: the snapshot gains the
    // deferred_launches key but no faults section.
    let snap = snapshot(&out);
    assert!(snap.contains("\"deferred_launches\""));
    assert!(!snap.contains("\"faults\""));
}

/// A launch whose footprint exceeds EVERY device the pool could ever
/// hold is permanently infeasible: counted as failed immediately, never
/// parked, never retried.
#[test]
fn launch_too_big_for_any_device_fails_immediately() {
    use dnnscaler::gpusim::{GpuSim, GpuSpec};

    let small = paper_job(1).unwrap();
    let big = paper_job(3).unwrap();
    let small_fp =
        GpuSim::for_paper_dnn(small.dnn, small.dataset, 0).unwrap().mem_demand_mb(1, 1);
    let big_fp = GpuSim::for_paper_dnn(big.dnn, big.dataset, 0).unwrap().mem_demand_mb(1, 1);
    // A card that serves the small job fine but can never hold the big
    // one, no matter what retires.
    let gpu = GpuSpec { mem_mb: (small_fp * 1.5).min(big_fp * 0.9), ..TESLA_P40 };
    assert!(gpu.mem_mb >= small_fp, "precondition: the small job must fit");
    assert!(gpu.mem_mb < big_fp, "precondition: the big job must never fit");

    let out = Cluster::builder()
        .device(gpu)
        .job_with_arrivals(
            small,
            PolicySpec::Static { bs: 1, mtl: 1 },
            ArrivalPattern::poisson(15.0),
        )
        .churn(ChurnSchedule::new().launch(
            1,
            big,
            PolicySpec::Static { bs: 1, mtl: 1 },
            ArrivalPattern::poisson(15.0),
        ))
        .windows(5)
        .rounds_per_window(8)
        .seed(37)
        .build()
        .unwrap()
        .run()
        .unwrap();
    let dy = out.dynamics.as_ref().unwrap();
    assert_eq!(dy.failed_launches, 1, "an impossible footprint is a hard failure");
    assert_eq!(dy.deferred_launches, 0, "it must not sit in the pending queue");
    assert_eq!(dy.launches, 0);
    let served: usize = out.devices.iter().map(|d| d.fleet.members.len()).sum();
    assert_eq!(served, 1, "only the initial job ever serves");
    assert_eq!(out.audit(), Ok(()));
}
