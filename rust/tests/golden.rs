//! Golden-outcome regression fixtures.
//!
//! Each test runs a fully seeded closed-loop scenario, snapshots the
//! outcome to canonical JSON (`coordinator::snapshot`), and compares the
//! bytes against a fixture checked in under `tests/fixtures/`. Because
//! every layer underneath is deterministic (seeded device noise, seeded
//! arrivals, virtual time), ANY change to these bytes means serving
//! behaviour changed — device RNG consumption order, window accounting,
//! admission decisions, contention coupling. `PartitionMode::TimeShare`
//! fleets must keep reproducing these numbers byte-identically; spatial
//! modes get their own fixture so the granted path is pinned too.
//!
//! Lifecycle:
//! * fixture missing  -> it is written (blessed) and the test passes —
//!   commit the new file to establish the baseline;
//! * `REGEN_FIXTURES=1` -> fixtures are rewritten unconditionally
//!   (`make test-fixtures` drives this and fails on `git diff`);
//! * otherwise        -> byte-exact comparison, with a diff pointer on
//!   mismatch.

use dnnscaler::coordinator::job::paper_job;
use dnnscaler::coordinator::session::{PolicySpec, RunConfig, ServingSession};
use dnnscaler::coordinator::snapshot::{fleet_outcome_to_json, job_outcome_to_json, render};
use dnnscaler::coordinator::Fleet;
use dnnscaler::gpusim::{GpuSim, PartitionMode};

use std::path::PathBuf;

fn fixture_path(name: &str) -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("tests/fixtures").join(name)
}

/// Compare `got` against the named fixture, blessing it when absent or
/// when `REGEN_FIXTURES` is set.
fn assert_matches_fixture(name: &str, got: &str) {
    let path = fixture_path(name);
    let regen = std::env::var_os("REGEN_FIXTURES").is_some();
    if regen || !path.exists() {
        std::fs::create_dir_all(path.parent().unwrap()).unwrap();
        std::fs::write(&path, got).unwrap();
        println!(
            "golden: {} fixture {name} ({} bytes) — commit it to pin the baseline",
            if regen { "regenerated" } else { "blessed new" },
            got.len()
        );
        return;
    }
    let want = std::fs::read_to_string(&path).unwrap();
    assert_eq!(
        want, got,
        "\ngolden fixture drift: {name}\n\
         Serving outcomes changed byte-for-byte. If this is an intended\n\
         behaviour change, regenerate with `make test-fixtures` and commit\n\
         the diff; otherwise the refactor broke determinism.\n"
    );
}

#[test]
fn golden_closed_loop_session() {
    // The paper's own serving mode: closed-loop DNNScaler on job 1
    // (profiler -> MT scaler), everything seeded.
    let job = paper_job(1).unwrap();
    let sim = GpuSim::for_paper_dnn(job.dnn, job.dataset, 7).unwrap();
    let out = ServingSession::builder()
        .config(RunConfig::windows(12, 10))
        .job(job)
        .device(sim)
        .policy(PolicySpec::DnnScaler)
        .build()
        .unwrap()
        .run()
        .unwrap();
    assert_matches_fixture("session_closed_dnnscaler.json", &render(&job_outcome_to_json(&out)));
}

#[test]
fn golden_closed_loop_three_member_fleet() {
    // The PR 2 shared-GPU baseline: three DNNs in lockstep windows under
    // TimeShare (the default). This is the byte-identity contract the
    // partition refactor must keep.
    let out = Fleet::builder()
        .windows(12)
        .rounds_per_window(8)
        .seed(7)
        .job(paper_job(1).unwrap(), PolicySpec::DnnScaler)
        .job(paper_job(3).unwrap(), PolicySpec::DnnScaler)
        .job(paper_job(4).unwrap(), PolicySpec::DnnScaler)
        .build()
        .unwrap()
        .run()
        .unwrap();
    assert_eq!(out.partition, PartitionMode::TimeShare);
    assert_matches_fixture("fleet_closed_3member.json", &render(&fleet_outcome_to_json(&out)));
}

#[test]
fn golden_mps_partitioned_fleet() {
    // The spatial path gets its own baseline: a 2-member MPS fleet with
    // explicit reservations, closed loop for full determinism.
    let out = Fleet::builder()
        .windows(10)
        .rounds_per_window(8)
        .seed(11)
        .partition_mode(PartitionMode::Mps)
        .job(paper_job(1).unwrap(), PolicySpec::Static { bs: 2, mtl: 2 })
        .sm_reservation(0.6)
        .job(paper_job(4).unwrap(), PolicySpec::Static { bs: 1, mtl: 4 })
        .sm_reservation(0.4)
        .build()
        .unwrap()
        .run()
        .unwrap();
    assert!(out.contention_trace.iter().all(|&c| c <= 1.0 + 1e-9));
    assert_matches_fixture("fleet_mps_2member.json", &render(&fleet_outcome_to_json(&out)));
}
