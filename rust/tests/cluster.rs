//! Cluster-layer tests: the Fleet-equivalence contract, the golden
//! heterogeneous fixture, placement feasibility properties, and the
//! interference-aware-beats-round-robin acceptance scenario.
//!
//! The golden fixture follows the PR 3 lifecycle: missing -> blessed
//! (commit it), `REGEN_FIXTURES=1` -> rewritten, otherwise byte-diffed.

use dnnscaler::coordinator::cluster::{
    BestFit, Cluster, DeviceDesc, InterferenceAware, Placement, PlacementJob, RoundRobin,
};
use dnnscaler::coordinator::dynamics;
use dnnscaler::coordinator::job::{paper_job, PAPER_JOBS};
use dnnscaler::coordinator::session::{PolicySpec, RunConfig};
use dnnscaler::coordinator::snapshot::{cluster_outcome_to_json, fleet_outcome_to_json, render};
use dnnscaler::coordinator::Fleet;
use dnnscaler::gpusim::{paper_profile, perf, GpuSpec, TESLA_P4, TESLA_P40, TESLA_T4};
use dnnscaler::rng::Rng;
use dnnscaler::workload::ArrivalPattern;

use std::path::PathBuf;

fn fixture_path(name: &str) -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("tests/fixtures").join(name)
}

/// Same lifecycle as tests/golden.rs: bless when absent or regenerating,
/// byte-compare otherwise.
fn assert_matches_fixture(name: &str, got: &str) {
    let path = fixture_path(name);
    let regen = std::env::var_os("REGEN_FIXTURES").is_some();
    if regen || !path.exists() {
        std::fs::create_dir_all(path.parent().unwrap()).unwrap();
        std::fs::write(&path, got).unwrap();
        println!(
            "golden: {} fixture {name} ({} bytes) — commit it to pin the baseline",
            if regen { "regenerated" } else { "blessed new" },
            got.len()
        );
        return;
    }
    let want = std::fs::read_to_string(&path).unwrap();
    assert_eq!(
        want, got,
        "\ngolden fixture drift: {name}\n\
         Cluster serving outcomes changed byte-for-byte. If intended,\n\
         regenerate with `make test-fixtures` and commit the diff.\n"
    );
}

// ---------------------------------------------------------------------------
// Fleet equivalence: a single-device cluster IS the fleet
// ---------------------------------------------------------------------------

#[test]
fn single_device_cluster_reproduces_open_loop_fleet_byte_for_byte() {
    // Same jobs, same policies, same knobs, same seed: the fleet's
    // outcome snapshot and the 1-device cluster's device snapshot must
    // be BYTE-identical — the cluster really is the fleet engine lifted
    // over devices, not a reimplementation that merely agrees on
    // averages.
    let fleet = Fleet::builder()
        .windows(10)
        .rounds_per_window(8)
        .seed(13)
        .job_with_arrivals(
            paper_job(1).unwrap(),
            PolicySpec::DnnScaler,
            ArrivalPattern::poisson(40.0),
        )
        .queue_capacity(128)
        .job_with_arrivals(
            paper_job(4).unwrap(),
            PolicySpec::QueueAware,
            ArrivalPattern::bursty(25.0, 3.0, 4.0, 1.0),
        )
        .shed_deadline(true)
        .job_with_arrivals(
            paper_job(5).unwrap(),
            PolicySpec::Static { bs: 2, mtl: 2 },
            ArrivalPattern::poisson(15.0),
        )
        .batch_timeout_ms(3.0)
        .build()
        .unwrap()
        .run()
        .unwrap();
    let cluster = Cluster::builder()
        .device(TESLA_P40)
        .windows(10)
        .rounds_per_window(8)
        .seed(13)
        .job_with_arrivals(
            paper_job(1).unwrap(),
            PolicySpec::DnnScaler,
            ArrivalPattern::poisson(40.0),
        )
        .queue_capacity(128)
        .job_with_arrivals(
            paper_job(4).unwrap(),
            PolicySpec::QueueAware,
            ArrivalPattern::bursty(25.0, 3.0, 4.0, 1.0),
        )
        .shed_deadline(true)
        .job_with_arrivals(
            paper_job(5).unwrap(),
            PolicySpec::Static { bs: 2, mtl: 2 },
            ArrivalPattern::poisson(15.0),
        )
        .batch_timeout_ms(3.0)
        .placement(RoundRobin::new())
        .build()
        .unwrap()
        .run()
        .unwrap();
    assert_eq!(cluster.devices.len(), 1);
    assert_eq!(cluster.assignment, vec![0, 0, 0]);
    let fleet_bytes = render(&fleet_outcome_to_json(&fleet));
    let cluster_bytes = render(&fleet_outcome_to_json(&cluster.devices[0].fleet));
    assert_eq!(
        fleet_bytes, cluster_bytes,
        "single-device cluster diverged from the fleet engine"
    );
}

#[test]
fn single_device_cluster_reproduces_closed_loop_fleet_byte_for_byte() {
    let fleet = Fleet::builder()
        .windows(12)
        .rounds_per_window(8)
        .seed(7)
        .job(paper_job(1).unwrap(), PolicySpec::DnnScaler)
        .job(paper_job(3).unwrap(), PolicySpec::Clipper)
        .job(paper_job(4).unwrap(), PolicySpec::Static { bs: 2, mtl: 2 })
        .build()
        .unwrap()
        .run()
        .unwrap();
    let cluster = Cluster::builder()
        .device(TESLA_P40)
        .windows(12)
        .rounds_per_window(8)
        .seed(7)
        .job(paper_job(1).unwrap(), PolicySpec::DnnScaler)
        .job(paper_job(3).unwrap(), PolicySpec::Clipper)
        .job(paper_job(4).unwrap(), PolicySpec::Static { bs: 2, mtl: 2 })
        .build()
        .unwrap()
        .run()
        .unwrap();
    assert_eq!(
        render(&fleet_outcome_to_json(&fleet)),
        render(&fleet_outcome_to_json(&cluster.devices[0].fleet)),
        "closed-loop single-device cluster diverged from the fleet engine"
    );
}

// ---------------------------------------------------------------------------
// Golden fixture: heterogeneous 2-physical-GPU cluster
// ---------------------------------------------------------------------------

#[test]
fn golden_heterogeneous_cluster() {
    // One whole GPU plus two MIG virtual devices carved from a second
    // card (the issue's canonical heterogeneous pool), three open-loop
    // jobs placed by memory best-fit. Fully seeded, so these bytes pin
    // placement, per-device admission, slice-as-device execution, and
    // outcome aggregation at once.
    let out = Cluster::builder()
        .device(TESLA_T4)
        .mig_device(TESLA_P40, 2)
        .job_with_arrivals(
            paper_job(1).unwrap(),
            PolicySpec::Static { bs: 1, mtl: 2 },
            ArrivalPattern::poisson(40.0),
        )
        .job_with_arrivals(
            paper_job(5).unwrap(),
            PolicySpec::Static { bs: 1, mtl: 2 },
            ArrivalPattern::poisson(30.0),
        )
        .job_with_arrivals(
            paper_job(4).unwrap(),
            PolicySpec::Static { bs: 1, mtl: 1 },
            ArrivalPattern::poisson(20.0),
        )
        .placement(BestFit::new())
        .windows(8)
        .rounds_per_window(8)
        .seed(11)
        .build()
        .unwrap()
        .run()
        .unwrap();
    assert_matches_fixture("cluster_hetero_3dev.json", &render(&cluster_outcome_to_json(&out)));
}

// ---------------------------------------------------------------------------
// Placement feasibility property
// ---------------------------------------------------------------------------

fn random_device(rng: &mut Rng, physical: usize) -> DeviceDesc {
    let spec: GpuSpec = [TESLA_P40, TESLA_T4, TESLA_P4][rng.below(3)].clone();
    // Whole cards and synthetic fractions (a slice-as-device stand-in).
    let fraction = match rng.below(3) {
        0 => 1.0,
        1 => 0.5,
        _ => 0.25,
    };
    DeviceDesc {
        name: format!("dev{physical}"),
        perf_fraction: (spec.peak_tflops / TESLA_P40.peak_tflops).min(1.0) * fraction,
        mem_mb: spec.mem_mb * fraction,
        price_per_hour: dynamics::price_per_hour(&spec) * fraction,
        spec,
        physical,
        slice: None,
    }
}

fn random_job(rng: &mut Rng) -> PlacementJob {
    let spec = PAPER_JOBS[rng.below(PAPER_JOBS.len())];
    let p = paper_profile(spec.dnn).unwrap();
    let burstiness = if rng.chance(0.4) { rng.uniform_range(1.0, 8.0) } else { 1.0 };
    PlacementJob {
        spec,
        mem_floor_mb: perf::mem_demand_mb(&p, 1, 1),
        sm_demand: perf::residency(&p, 1),
        mean_rate: rng.uniform_range(1.0, 200.0),
        burstiness,
    }
}

#[test]
fn prop_every_placement_is_feasible_or_typed_error() {
    // For arbitrary job mixes and device pools, EVERY placer either
    // returns an assignment that validates (every job placed, every
    // index in range, no device memory over-commit) or a typed
    // PlacementError — never a silently infeasible assignment, never a
    // panic.
    for seed in 0..300u64 {
        let mut rng = Rng::new(0xC1_05_7E_12 ^ seed.wrapping_mul(0x9E3779B97F4A7C15));
        let devices: Vec<DeviceDesc> =
            (0..1 + rng.below(4)).map(|i| random_device(&mut rng, i)).collect();
        let jobs: Vec<PlacementJob> = (0..1 + rng.below(8)).map(|_| random_job(&mut rng)).collect();
        let mut placers: Vec<Box<dyn Placement>> = vec![
            Box::new(RoundRobin::new()),
            Box::new(BestFit::new()),
            Box::new(InterferenceAware::new()),
        ];
        for placer in &mut placers {
            match placer.place(&jobs, &devices) {
                Ok(a) => {
                    a.validate(&jobs, &devices).unwrap_or_else(|e| {
                        panic!(
                            "seed {seed}: {} returned an infeasible assignment {:?}: {e}",
                            placer.name(),
                            a.device_of
                        )
                    });
                    assert_eq!(a.device_of.len(), jobs.len(), "seed {seed}: job dropped");
                }
                // A typed refusal is a legitimate outcome (e.g. nothing
                // fits); the property here is that an Ok is never a lie.
                // (Refusal-completeness is NOT asserted: the greedy
                // placers order jobs differently, and a greedy order can
                // fail on a set another order packs.)
                Err(_) => {}
            }
        }
    }
}

// ---------------------------------------------------------------------------
// Acceptance: interference-aware beats round robin under bursty neighbours
// ---------------------------------------------------------------------------

/// Two bursty SM hogs (inc-v4 at 4 instances: ~0.9 residency each, load
/// near capacity) and two tiny smooth jobs, ordered so round robin
/// co-locates the hogs on device 0. Time-sharing two hogs cuts each
/// one's capacity below its offered load -> sustained backlog -> the
/// sojourn tail blows the SLO -> goodput collapses. Interference-aware
/// placement puts one hog per device and keeps everyone stable.
fn bursty_neighbour_cluster(placement: impl Placement + 'static) -> dnnscaler::ClusterOutcome {
    let hog = paper_job(3).unwrap(); // inc-v4, SLO 419 ms
    let smooth = paper_job(5).unwrap(); // mobv1-025, SLO 186 ms
    Cluster::builder()
        .device(TESLA_P40)
        .device(TESLA_P40)
        .job_with_arrivals(
            hog,
            PolicySpec::Static { bs: 1, mtl: 4 },
            ArrivalPattern::bursty(24.0, 4.0, 2.0, 0.5),
        )
        .job_with_arrivals(
            smooth,
            PolicySpec::Static { bs: 1, mtl: 2 },
            ArrivalPattern::poisson(30.0),
        )
        .job_with_arrivals(
            hog,
            PolicySpec::Static { bs: 1, mtl: 4 },
            ArrivalPattern::bursty(24.0, 4.0, 2.0, 0.5),
        )
        .job_with_arrivals(
            smooth,
            PolicySpec::Static { bs: 1, mtl: 2 },
            ArrivalPattern::poisson(30.0),
        )
        .placement(placement)
        .windows(16)
        .rounds_per_window(20)
        .seed(17)
        .build()
        .unwrap()
        .run()
        .unwrap()
}

#[test]
fn interference_aware_beats_round_robin_on_goodput() {
    let rr = bursty_neighbour_cluster(RoundRobin::new());
    let ia = bursty_neighbour_cluster(InterferenceAware::new());
    // The scenario is only meaningful if the placements actually differ
    // the way the setup intends.
    assert_eq!(
        rr.assignment[0], rr.assignment[2],
        "round robin was supposed to co-locate the hogs: {:?}",
        rr.assignment
    );
    assert_ne!(
        ia.assignment[0], ia.assignment[2],
        "interference-aware was supposed to separate the hogs: {:?}",
        ia.assignment
    );
    // Identical offered load (same job seeds regardless of placement):
    // separating the bursty hogs must win on total goodput — the
    // acceptance criterion.
    assert!(
        ia.total_goodput > rr.total_goodput,
        "interference-aware goodput {:.1} must beat round robin {:.1}",
        ia.total_goodput,
        rr.total_goodput
    );
    // And the win comes from the hogs' tails, not an accounting quirk:
    // under RR the co-located hogs' joint goodput collapses vs IA's.
    let hog_goodput = |out: &dnnscaler::ClusterOutcome| -> f64 {
        out.devices
            .iter()
            .flat_map(|d| d.fleet.members.iter())
            .filter(|m| m.dnn == "inc-v4")
            .map(|m| m.goodput)
            .sum()
    };
    assert!(
        hog_goodput(&ia) > hog_goodput(&rr),
        "hog goodput: ia {:.1} vs rr {:.1}",
        hog_goodput(&ia),
        hog_goodput(&rr)
    );
}

// ---------------------------------------------------------------------------
// Assignment surface sanity
// ---------------------------------------------------------------------------

#[test]
fn cluster_reports_placement_metadata() {
    let out = Cluster::builder()
        .device(TESLA_P40)
        .device(TESLA_P4)
        .job_with_arrivals(
            paper_job(1).unwrap(),
            PolicySpec::Static { bs: 1, mtl: 1 },
            ArrivalPattern::poisson(10.0),
        )
        .job_with_arrivals(
            paper_job(5).unwrap(),
            PolicySpec::Static { bs: 1, mtl: 1 },
            ArrivalPattern::poisson(10.0),
        )
        .placement(RoundRobin::new())
        .windows(4)
        .rounds_per_window(4)
        .build()
        .unwrap()
        .run()
        .unwrap();
    assert_eq!(out.placement, "rr");
    assert_eq!(out.assignment, vec![0, 1]);
    assert_eq!(out.devices[0].jobs, vec![0]);
    assert_eq!(out.devices[1].jobs, vec![1]);
    // Totals aggregate the per-device fleets.
    let sum: f64 = out.devices.iter().map(|d| d.fleet.total_throughput).sum();
    assert!((out.total_throughput - sum).abs() < 1e-9);
    // The validated assignment survives into a feasible serve: the P4
    // device's admission ceiling is its own 8 GB, not the P40's.
    assert_eq!(out.devices[1].fleet.mem_capacity_mb, TESLA_P4.mem_mb);
}
