//! Integration + property tests for the shared open-loop serving engine
//! (PR 2): trace-replay fidelity, SLO deadline-shed accounting, and
//! open-loop fleets with per-member arrivals — including the cross-job
//! burst-interference scenario where one member's burst degrades a
//! steady co-located member's tail via SM contention, then re-converges.

use dnnscaler::coordinator::job::paper_job;
use dnnscaler::coordinator::session::{ConfigError, PolicySpec, RunConfig, ServingSession};
use dnnscaler::coordinator::Fleet;
use dnnscaler::gpusim::GpuSim;
use dnnscaler::rng::Rng;
use dnnscaler::workload::{ArrivalGenerator, ArrivalPattern, RequestQueue, TraceError};

// ---------------------------------------------------------------------------
// Trace replay fidelity
// ---------------------------------------------------------------------------

#[test]
fn prop_trace_replay_emits_exactly_the_trace_in_order() {
    // For random sorted traces, the generator must emit exactly the
    // recorded timestamps, in order, and arrivals_until(horizon) must be
    // exactly the prefix below the horizon.
    for seed in 0..60u64 {
        let mut rng = Rng::new(0x7ACE ^ seed.wrapping_mul(0x9E3779B97F4A7C15));
        let n = rng.below(150) + 1;
        let mut ts = Vec::with_capacity(n);
        let mut t = 0.0;
        for _ in 0..n {
            t += rng.uniform_range(0.0, 0.05); // zero gaps allowed
            ts.push(t);
        }
        let horizon = t * 0.6 + 1e-4;
        let pattern = ArrivalPattern::trace(ts.clone()).unwrap();
        assert_eq!(pattern.mean_rate(), ts.len() as f64 / t, "seed {seed}");

        let mut g = ArrivalGenerator::new(pattern, seed);
        let got = g.arrivals_until(horizon);
        let want: Vec<f64> = ts.iter().copied().filter(|x| *x < horizon).collect();
        assert_eq!(got, want, "seed {seed}: prefix below horizon");

        // arrivals_until must not LOSE the first timestamp at or past the
        // horizon: every remaining recorded arrival still replays, in
        // order, via either next_arrival or a second arrivals_until.
        let rest: Vec<f64> = ts.iter().copied().skip(want.len()).collect();
        let (head, tail) = rest.split_at(rest.len() / 2);
        for &x in head {
            assert_eq!(g.next_arrival(), x, "seed {seed}: lost an arrival");
        }
        assert_eq!(g.arrivals_until(f64::INFINITY), tail, "seed {seed}: tail replay");
        assert_eq!(g.next_arrival(), f64::INFINITY, "seed {seed}: exhausted");
        assert_eq!(g.next_arrival(), f64::INFINITY, "seed {seed}: stays exhausted");
    }
}

#[test]
fn session_serves_a_finite_trace_exactly_once() {
    // A session fed a finite trace must admit exactly the trace's
    // requests, serve all of them (ample capacity, unbounded queue), and
    // then go idle for the remaining windows.
    let ts: Vec<f64> = (0..300).map(|i| i as f64 * 0.004).collect(); // 300 reqs in 1.2 s
    let job = paper_job(1).unwrap();
    let sim = GpuSim::for_paper_dnn(job.dnn, job.dataset, 13).unwrap();
    let out = ServingSession::builder()
        .config(RunConfig::windows(25, 12))
        .job(job)
        .device(sim)
        .policy(PolicySpec::Static { bs: 1, mtl: 4 })
        .arrivals(ArrivalPattern::trace(ts).unwrap())
        .batch_timeout_ms(4.0)
        .seed(13)
        .build()
        .unwrap()
        .run()
        .unwrap();
    assert_eq!(out.arrived, 300, "every trace timestamp must arrive");
    let served: f64 = out.latencies.iter().map(|(_, w)| w).sum();
    assert_eq!(served, 300.0, "every arrived request must be served");
    assert_eq!(out.drops, 0);
    assert_eq!(out.dropped_deadline, 0);
    // After the trace drains, windows are honestly idle.
    let last = out.trace.last().unwrap();
    assert_eq!(last.throughput, 0.0, "exhausted trace must leave idle windows");
    assert_eq!(last.arrival_rate, 0.0);
}

/// The shipped Azure-Functions-style arrival trace (see `data/`).
fn azure_trace_path() -> std::path::PathBuf {
    std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("../data/azure_functions_sample.txt")
}

#[test]
fn shipped_azure_trace_parses_and_replays_to_completion() {
    // The checked-in trace must validate (sorted, finite, non-negative)
    // and carry the documented shape: a ~60 s span at roughly 9 req/s
    // with timer-spike bursts.
    let pattern = ArrivalPattern::from_trace_file(azure_trace_path())
        .expect("data/azure_functions_sample.txt must parse");
    let ArrivalPattern::Streamed(src) = &pattern else {
        panic!("trace file must produce a streamed pattern")
    };
    let n = src.len();
    assert!(n > 400, "trace is suspiciously small: {n} arrivals");
    assert!(src.last_s() <= 60.0, "trace must be normalized to a 60 s span");
    let rate = pattern.mean_rate();
    assert!((5.0..15.0).contains(&rate), "mean rate {rate:.2}/s out of the documented band");

    // Replay it end to end: a lightly loaded static point must admit
    // every recorded arrival, serve all of them, and drop none.
    let job = paper_job(1).unwrap();
    let sim = GpuSim::for_paper_dnn(job.dnn, job.dataset, 17).unwrap();
    let out = ServingSession::builder()
        .config(RunConfig::windows(60, 20))
        .job(job)
        .device(sim)
        .policy(PolicySpec::Static { bs: 1, mtl: 4 })
        .arrivals(pattern)
        .batch_timeout_ms(5.0)
        .seed(17)
        .build()
        .unwrap()
        .run()
        .unwrap();
    assert_eq!(out.arrived as usize, n, "every recorded arrival must be admitted");
    let served: f64 = out.latencies.iter().map(|(_, w)| w).sum();
    assert_eq!(served as usize, n, "every admitted request must be served");
    assert_eq!(out.drops, 0);
    assert_eq!(out.dropped_deadline, 0);
    // The burst structure must be visible to policies: some window sees
    // well above the mean offered rate.
    assert!(
        out.trace.iter().any(|r| r.arrival_rate > 1.5 * rate),
        "timer spikes never surfaced in the per-window arrival telemetry"
    );
}

#[test]
fn builder_surfaces_trace_errors_as_config_errors() {
    let job = paper_job(1).unwrap();
    let sim = GpuSim::for_paper_dnn(job.dnn, job.dataset, 1).unwrap();
    let err = ServingSession::builder()
        .job(job)
        .device(sim)
        .arrivals(ArrivalPattern::Trace(vec![2.0, 1.0]))
        .build()
        .err()
        .unwrap();
    assert_eq!(
        err,
        ConfigError::BadTrace(TraceError::Unsorted { index: 1, prev: 2.0, t: 1.0 })
    );
}

// ---------------------------------------------------------------------------
// Deadline-shed accounting
// ---------------------------------------------------------------------------

#[test]
fn prop_shed_accounting_balances_under_random_traffic() {
    // Invariant at every step: push attempts == served (taken) +
    // capacity-dropped + deadline-shed + still queued.
    for seed in 0..120u64 {
        let mut rng = Rng::new(0x5EED ^ seed.wrapping_mul(0x9E3779B97F4A7C15));
        let cap = rng.below(8) + 1;
        let mut q = RequestQueue::bounded(cap);
        let mut clock = 0.0f64;
        let mut pushed = 0u64;
        let mut taken = 0u64;
        for _ in 0..200 {
            match rng.below(3) {
                0 => {
                    clock += rng.uniform_range(0.0, 0.2);
                    let _ = q.push(clock);
                    pushed += 1;
                }
                1 => {
                    taken += q.take_batch(rng.below(4) + 1).len() as u64;
                }
                _ => {
                    clock += rng.uniform_range(0.0, 0.3);
                    q.shed_expired(clock, rng.uniform_range(0.0, 150.0));
                }
            }
            assert_eq!(
                pushed,
                taken + q.dropped + q.dropped_deadline + q.len() as u64,
                "seed {seed}: accounting must balance"
            );
            assert!(q.len() <= cap, "seed {seed}");
        }
    }
}

#[test]
fn overloaded_session_sheds_and_reports_goodput() {
    // Heavy Poisson load on a slow static point with a bounded queue and
    // shedding on: requests that can no longer meet the SLO are shed
    // (counted separately from capacity drops), and the outcome's
    // accounting ties out.
    let job = paper_job(3).unwrap(); // inc-v4: slow per-batch
    let sim = GpuSim::for_paper_dnn(job.dnn, job.dataset, 7).unwrap();
    let out = ServingSession::builder()
        .config(RunConfig::windows(6, 8))
        .job(job)
        .device(sim)
        .policy(PolicySpec::Static { bs: 1, mtl: 1 })
        .arrivals(ArrivalPattern::poisson(400.0))
        .queue_capacity(64)
        .batch_timeout_ms(2.0)
        .shed_deadline(true)
        .seed(7)
        .build()
        .unwrap()
        .run()
        .unwrap();
    assert!(out.dropped_deadline > 0, "expired backlog must be shed");
    assert!(out.drops > 0, "the bounded queue must also overflow");
    let served: f64 = out.latencies.iter().map(|(_, w)| w).sum();
    let accounted = served as u64 + out.drops + out.dropped_deadline;
    assert!(accounted <= out.arrived, "served+dropped+shed cannot exceed arrivals");
    assert!(
        out.arrived - accounted <= 64,
        "only the final queue residue (<= capacity) may be unaccounted: {} vs {}",
        out.arrived,
        accounted
    );
    // Per-window shed telemetry sums to the run total.
    let window_shed: u64 = out.trace.iter().map(|r| r.drops_deadline).sum();
    assert_eq!(window_shed, out.dropped_deadline);
    // Goodput is SLO-met throughput: never more than raw throughput, and
    // consistent with the steady attainment it is derived from.
    assert!(out.goodput <= out.throughput + 1e-9);
    assert!((out.goodput - out.throughput * out.steady_attainment).abs() < 1e-9);
}

#[test]
fn shedding_never_fires_when_disabled() {
    // Same overload, shedding off: dropped_deadline must stay zero.
    let job = paper_job(3).unwrap();
    let sim = GpuSim::for_paper_dnn(job.dnn, job.dataset, 7).unwrap();
    let out = ServingSession::builder()
        .config(RunConfig::windows(6, 8))
        .job(job)
        .device(sim)
        .policy(PolicySpec::Static { bs: 1, mtl: 1 })
        .arrivals(ArrivalPattern::poisson(400.0))
        .queue_capacity(64)
        .batch_timeout_ms(2.0)
        .seed(7)
        .build()
        .unwrap()
        .run()
        .unwrap();
    assert_eq!(out.dropped_deadline, 0);
    assert!(out.trace.iter().all(|r| r.drops_deadline == 0));
}

// ---------------------------------------------------------------------------
// Open-loop fleet: cross-job burst interference
// ---------------------------------------------------------------------------

/// The steady member: light Poisson load on a fixed multi-instance point.
/// Identical (same policy, same arrival seed, same device seed) in both
/// fleets below, so any difference in its observed tail is *caused by its
/// neighbour* through the shared-SM contention factor.
fn steady_member(
    b: dnnscaler::coordinator::FleetBuilder<'static>,
) -> dnnscaler::coordinator::FleetBuilder<'static> {
    b.job_with_arrivals(
        paper_job(4).unwrap(), // mobv1-05: SM share climbs with instances
        PolicySpec::Static { bs: 1, mtl: 8 },
        ArrivalPattern::poisson(25.0),
    )
}

/// One dense burst early on, then silence: 800 requests in 0.8 s —
/// several windows of backlog for the bursty member (inc-v1 serves
/// ~100+/s at one instance), fully arrived well before the run ends.
fn burst_trace() -> ArrivalPattern {
    ArrivalPattern::trace((0..800).map(|i| i as f64 * 0.001).collect()).unwrap()
}

#[test]
fn bursty_member_degrades_steady_neighbour_then_reconverges() {
    let windows = 48;
    // Quiet twin: the neighbour holds (1, 1) forever, so the contention
    // factor never moves.
    let quiet = steady_member(Fleet::builder().windows(windows).rounds_per_window(20).seed(23))
        .job_with_arrivals(
            paper_job(1).unwrap(), // inc-v1: high per-instance SM share
            PolicySpec::Static { bs: 1, mtl: 1 },
            burst_trace(),
        )
        .build()
        .unwrap()
        .run()
        .unwrap();
    // Loud twin: the queue-aware policy sees the burst backlog and scales
    // the neighbour up, raising combined SM pressure past saturation.
    let loud = steady_member(Fleet::builder().windows(windows).rounds_per_window(20).seed(23))
        .job_with_arrivals(paper_job(1).unwrap(), PolicySpec::QueueAware, burst_trace())
        .build()
        .unwrap()
        .run()
        .unwrap();

    // The burst trace replays identically through both fleets.
    assert_eq!(quiet.members[1].arrived, 800);
    assert_eq!(loud.members[1].arrived, 800);

    // The neighbour actually scaled up under the burst, then backed off
    // once the backlog drained and the trace went silent (re-convergence).
    let b_mtl: Vec<u32> = loud.members[1].trace.iter().map(|r| r.mtl).collect();
    let b_peak = *b_mtl.iter().max().unwrap();
    assert!(b_peak >= 4, "queue-aware member never scaled up: peak mtl {b_peak}");
    assert!(
        *b_mtl.last().unwrap() <= 2,
        "queue-aware member never re-converged: final mtl {} (peak {b_peak})",
        b_mtl.last().unwrap()
    );

    // Interference is visible in the shared-SM telemetry: contention
    // rises above the quiet twin's constant level and above saturation,
    // then falls back by the final window.
    assert!(
        loud.peak_contention > quiet.peak_contention + 0.05,
        "scale-up must raise combined SM pressure ({:.2} vs {:.2})",
        loud.peak_contention,
        quiet.peak_contention
    );
    assert!(
        loud.peak_contention > 1.0,
        "burst must push the fleet into time-sharing (contention {:.2})",
        loud.peak_contention
    );
    let last_contention = *loud.contention_trace.last().unwrap();
    assert!(
        last_contention < loud.peak_contention - 0.02,
        "contention must re-converge: final {last_contention:.2} vs peak {:.2}",
        loud.peak_contention
    );

    // ... and in the steady member's tail: same arrivals, same device
    // noise, same operating point — only the contention factor differs —
    // so some burst-era window must show a visibly inflated p95.
    let a_quiet = &quiet.members[0].trace;
    let a_loud = &loud.members[0].trace;
    assert!(
        a_loud
            .iter()
            .zip(a_quiet)
            .any(|(l, q)| l.p95_ms > q.p95_ms * 1.05),
        "steady member's p95 never degraded under the neighbour's burst"
    );
    // Re-convergence on the victim side too: once the neighbour has
    // backed off, the steady member's tail returns to its quiet level.
    let tail_mean = |t: &[dnnscaler::coordinator::WindowRecord]| {
        let tail = &t[t.len() - 4..];
        tail.iter().map(|r| r.p95_ms).sum::<f64>() / tail.len() as f64
    };
    assert!(
        tail_mean(a_loud) <= tail_mean(a_quiet) * 1.3,
        "steady member's tail must recover: {:.2} vs {:.2}",
        tail_mean(a_loud),
        tail_mean(a_quiet)
    );
}
