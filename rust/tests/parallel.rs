//! Differential suite for the data-parallel cluster runner (PR 7):
//! random cluster configurations — heterogeneous device mixes, MIG
//! partitions, open- and closed-loop serving, churn / migration /
//! autoscaling schedules — must produce snapshot-BYTE-identical output
//! at every worker-thread count. Same pattern as the calendar-vs-
//! `LinearScan` scheduler suite: the serial engine (`threads(1)`) is
//! the reference, and `threads(2)` / `threads(8)` must reproduce its
//! bytes exactly.
//!
//! The contract this leans on: job `j` derives its simulator and
//! arrival streams from its GLOBAL index (`seed + j`,
//! `arrival_seed(seed, j)`), devices only interact at placement time
//! and window boundaries, and within one device the per-shard calendar
//! pops members in exactly the order the global calendar would.

use dnnscaler::coordinator::cluster::{
    BestFit, Cluster, ClusterOutcome, InterferenceAware, RoundRobin,
};
use dnnscaler::coordinator::dynamics::{ChurnSchedule, PeriodicReplace, ThresholdAutoscaler};
use dnnscaler::coordinator::job::paper_job;
use dnnscaler::coordinator::session::PolicySpec;
use dnnscaler::coordinator::snapshot::{cluster_outcome_to_json, render};
use dnnscaler::gpusim::{TESLA_P4, TESLA_P40, TESLA_T4};
use dnnscaler::rng::Rng;
use dnnscaler::workload::ArrivalPattern;

fn snapshot(out: &ClusterOutcome) -> String {
    render(&cluster_outcome_to_json(out))
}

/// A plain-data description of one random cluster configuration, so the
/// identical cluster can be rebuilt once per thread count (builders and
/// policies are consumed by `run`).
struct Case {
    seed: u64,
    windows: usize,
    rounds: usize,
    /// (gpu index into GPUS, mig slices; 0 = whole card)
    devices: Vec<(usize, u32)>,
    placement: usize,
    /// (paper job id, poisson rate; 0.0 = closed-loop, queue cap)
    jobs: Vec<(u32, f64, Option<usize>)>,
    churn: bool,
    migrate: bool,
    autoscale: bool,
}

const GPUS: [dnnscaler::gpusim::GpuSpec; 3] = [TESLA_P40, TESLA_T4, TESLA_P4];

impl Case {
    fn random(seed: u64, rng: &mut Rng) -> Case {
        let open = rng.chance(0.7);
        let dynamic = open && rng.chance(0.5);
        let n_dev = 1 + rng.below(4);
        let devices = (0..n_dev)
            .map(|_| {
                let gpu = rng.below(GPUS.len());
                // MIG only on the big cards: small-card slices undercut
                // the minimum SM grant and are refused at build time.
                let slices = if gpu == 0 && rng.chance(0.4) {
                    [2u32, 4u32][rng.below(2)]
                } else {
                    0
                };
                (gpu, slices)
            })
            .collect();
        let n_jobs = 1 + rng.below(6);
        let jobs = (0..n_jobs)
            .map(|_| {
                let id = 1 + rng.below(30) as u32;
                let rate = if open { rng.uniform_range(10.0, 60.0) } else { 0.0 };
                let cap = rng.chance(0.4).then(|| 16 + rng.below(64));
                (id, rate, cap)
            })
            .collect();
        Case {
            seed,
            windows: 3 + rng.below(3),
            rounds: 6 + rng.below(6),
            devices,
            placement: rng.below(3),
            jobs,
            churn: dynamic && rng.chance(0.7),
            migrate: dynamic && rng.chance(0.5),
            autoscale: dynamic && rng.chance(0.5),
        }
    }

    fn build(&self, threads: usize) -> Result<Cluster<'static>, dnnscaler::ConfigError> {
        let mut b = Cluster::builder()
            .windows(self.windows)
            .rounds_per_window(self.rounds)
            .seed(self.seed)
            .threads(threads);
        b = match self.placement {
            0 => b.placement(RoundRobin::new()),
            1 => b.placement(BestFit::new()),
            _ => b.placement(InterferenceAware::new()),
        };
        for &(gpu, slices) in &self.devices {
            b = if slices == 0 {
                b.device(GPUS[gpu].clone())
            } else {
                b.mig_device(GPUS[gpu].clone(), slices)
            };
        }
        for &(id, rate, cap) in &self.jobs {
            let job = paper_job(id).expect("paper job id in 1..=30");
            b = if rate > 0.0 {
                b.job_with_arrivals(
                    job,
                    PolicySpec::Static { bs: 2, mtl: 1 },
                    ArrivalPattern::poisson(rate),
                )
            } else {
                b.job(job, PolicySpec::Clipper)
            };
            if let Some(c) = cap {
                if rate > 0.0 {
                    b = b.queue_capacity(c);
                }
            }
        }
        if self.churn {
            let launched = *paper_job(7).unwrap();
            let w_launch = 1 % self.windows;
            let w_retire = self.windows - 1;
            let mut schedule = ChurnSchedule::new().launch(
                w_launch,
                &launched,
                PolicySpec::Static { bs: 2, mtl: 1 },
                ArrivalPattern::poisson(25.0),
            );
            if w_retire > w_launch {
                schedule = schedule.retire(w_retire, launched.id);
            }
            b = b.churn(schedule);
        }
        if self.migrate {
            b = b.placement_policy(PeriodicReplace::new(RoundRobin::new(), 2));
        }
        if self.autoscale {
            b = b.autoscaler(ThresholdAutoscaler::new(1, self.devices.len() + 2));
        }
        b.build()
    }
}

/// Run one case at the reference thread count and at each parallel
/// count; every snapshot must match the reference byte for byte.
fn assert_byte_identical(label: &str, case: &Case) {
    let reference = match case.build(1) {
        Ok(cluster) => snapshot(&cluster.run().expect("serial run")),
        // An infeasible random config (placement cannot fit the jobs)
        // must be equally infeasible at every thread count — the knob
        // only shards execution, never admission.
        Err(e) => {
            for &t in &[2usize, 8] {
                let parallel = case.build(t).err();
                assert!(parallel.is_some(), "{label}: threads {t} accepted a config serial refused ({e:?})");
            }
            return;
        }
    };
    for &t in &[2usize, 8] {
        let got = snapshot(
            &case.build(t).expect("parallel build matches serial").run().expect("parallel run"),
        );
        assert_eq!(
            got, reference,
            "{label}: threads {t} diverged from the serial engine"
        );
    }
}

#[test]
fn random_clusters_are_byte_identical_at_every_thread_count() {
    for seed in 0..24u64 {
        let mut rng = Rng::new(0xD1FF ^ seed.wrapping_mul(0x9E3779B97F4A7C15));
        let case = Case::random(seed, &mut rng);
        assert_byte_identical(&format!("case {seed}"), &case);
    }
}

#[test]
fn mig_mixed_pool_is_byte_identical_at_every_thread_count() {
    // Deterministic worst case for shard boundaries: more virtual
    // devices than workers, MIG slices mixed with whole cards, jobs of
    // very different rates.
    let case = Case {
        seed: 1234,
        windows: 5,
        rounds: 10,
        devices: vec![(0, 4), (1, 0), (0, 0), (2, 0)],
        placement: 1,
        jobs: (0..8).map(|i| (1 + i * 3 % 30, 15.0 + 10.0 * i as f64, Some(32))).collect(),
        churn: false,
        migrate: false,
        autoscale: false,
    };
    assert_byte_identical("mig mix", &case);
}

#[test]
fn dynamic_cluster_is_byte_identical_at_every_thread_count() {
    // Churn + migration + autoscaling all active: the window barrier
    // must keep every dynamics decision ordered exactly as the serial
    // engine orders it.
    let case = Case {
        seed: 77,
        windows: 6,
        rounds: 8,
        devices: vec![(0, 0), (1, 0), (1, 0)],
        placement: 0,
        jobs: (0..5).map(|i| (1 + i as u32, 20.0 + 5.0 * i as f64, None)).collect(),
        churn: true,
        migrate: true,
        autoscale: true,
    };
    assert_byte_identical("dynamics", &case);
}

#[test]
fn oversubscribed_thread_counts_collapse_to_available_shards() {
    // threads > devices must clamp, not wedge: a 2-device pool at 8
    // threads serves on 2 shards and still reproduces the serial bytes.
    let case = Case {
        seed: 5,
        windows: 4,
        rounds: 8,
        devices: vec![(1, 0), (2, 0)],
        placement: 0,
        jobs: vec![(3, 30.0, None), (9, 45.0, Some(24))],
        churn: false,
        migrate: false,
        autoscale: false,
    };
    assert_byte_identical("clamped threads", &case);
}
