//! Property + integration tests for spatial GPU partitioning (PR 3):
//! the SM pool can never over-grant under any admission interleaving,
//! MIG quantization is conservative, `PartitionMode::TimeShare`
//! reproduces the legacy fleet byte for byte, and an MPS-partitioned
//! fleet shows lower cross-member p95 interference than time-sharing
//! under the burst-interference scenario from `tests/serving_engine.rs`.

use dnnscaler::coordinator::job::paper_job;
use dnnscaler::coordinator::session::PolicySpec;
use dnnscaler::coordinator::{Fleet, FleetBuilder, FleetOutcome, WindowRecord};
use dnnscaler::gpusim::{plan_grants, quantize_to_slices, PartitionMode, SmPool, MIN_GRANT};
use dnnscaler::rng::Rng;
use dnnscaler::workload::ArrivalPattern;

// ---------------------------------------------------------------------------
// Pool + planner properties
// ---------------------------------------------------------------------------

#[test]
fn prop_pool_never_overgrants_under_any_interleaving() {
    // Random interleavings of grant and release: the invariant
    // `granted <= 1.0` must hold after every single operation, and a
    // refused grant must leave the ledger untouched.
    for seed in 0..200u64 {
        let mut rng = Rng::new(0x5B0_07 ^ seed.wrapping_mul(0x9E3779B97F4A7C15));
        let mut pool = SmPool::new();
        let mut held: Vec<f64> = Vec::new();
        for _ in 0..300 {
            if rng.below(2) == 0 {
                let f = rng.uniform_range(0.0, 0.7);
                let before = pool.granted();
                match pool.try_grant(f) {
                    Ok(()) => held.push(f),
                    Err(_) => {
                        assert!(
                            (pool.granted() - before).abs() < 1e-12,
                            "seed {seed}: refused grant mutated the ledger"
                        );
                    }
                }
            } else if let Some(f) = held.pop() {
                pool.release(f);
            }
            assert!(pool.granted() <= 1.0 + 1e-9, "seed {seed}: pool over-granted");
            assert!(pool.granted() >= -1e-12, "seed {seed}: negative grant total");
            assert!(pool.available() >= 0.0);
        }
    }
}

#[test]
fn prop_planned_grants_never_exceed_the_device() {
    // Random reservation vectors (mix of explicit fractions and
    // defaults) through every mode: any ACCEPTED plan sums to <= 1.0
    // with every grant positive, and every grant admits through a fresh
    // SmPool — the two layers can never disagree.
    for seed in 0..300u64 {
        let mut rng = Rng::new(0x9147 ^ seed.wrapping_mul(0x9E3779B97F4A7C15));
        let n = rng.below(6) + 1;
        let reservations: Vec<Option<f64>> = (0..n)
            .map(|_| {
                if rng.below(3) == 0 {
                    None
                } else {
                    Some(rng.uniform_range(0.0, 1.2)) // may be invalid on purpose
                }
            })
            .collect();
        let slices = rng.below(8) as u32 + 1;
        for mode in [
            PartitionMode::TimeShare,
            PartitionMode::Mps,
            PartitionMode::MigSlices { slices },
        ] {
            let Ok(grants) = plan_grants(mode, &reservations) else {
                continue; // rejections are the other property's subject
            };
            assert_eq!(grants.len(), reservations.len());
            if mode == PartitionMode::TimeShare {
                assert!(grants.iter().all(|&g| g == 1.0), "seed {seed}");
                continue;
            }
            let total: f64 = grants.iter().sum();
            assert!(total <= 1.0 + 1e-9, "seed {seed} {mode}: grants sum to {total}");
            assert!(grants.iter().all(|&g| g > 0.0), "seed {seed} {mode}: empty grant");
            let mut pool = SmPool::new();
            for &g in &grants {
                pool.try_grant(g).unwrap_or_else(|e| {
                    panic!("seed {seed} {mode}: planned grant refused admission: {e}")
                });
            }
        }
    }
}

#[test]
fn prop_mig_quantization_is_conservative() {
    // For every accepted MIG plan: each explicit member's grant never
    // exceeds its reservation, and every grant is a whole number of
    // slices. (Defaults are quantized down from their equal split.)
    for seed in 0..200u64 {
        let mut rng = Rng::new(0x3160 ^ seed.wrapping_mul(0x9E3779B97F4A7C15));
        let slices = rng.below(8) as u32 + 1;
        let n = rng.below(5) + 1;
        let reservations: Vec<Option<f64>> = (0..n)
            .map(|_| (rng.below(4) != 0).then(|| rng.uniform_range(MIN_GRANT, 1.0)))
            .collect();
        let Ok(grants) = plan_grants(PartitionMode::MigSlices { slices }, &reservations) else {
            continue;
        };
        for (i, (g, r)) in grants.iter().zip(&reservations).enumerate() {
            if let Some(r) = r {
                assert!(
                    *g <= r + 1e-9,
                    "seed {seed}: member {i} granted {g} > reserved {r} (slices {slices})"
                );
            }
            let units = g * slices as f64;
            assert!(
                (units - units.round()).abs() < 1e-9,
                "seed {seed}: grant {g} is not whole slices of 1/{slices}"
            );
            assert_eq!(*g, quantize_to_slices(*g, slices), "quantization must be idempotent");
        }
    }
}

// ---------------------------------------------------------------------------
// TimeShare byte-identity
// ---------------------------------------------------------------------------

/// The cross-job burst-interference scenario from `tests/serving_engine.rs`:
/// a steady multi-instance member next to a member slammed by one dense
/// early burst (800 requests in 0.8 s).
fn burst_fleet(windows: usize) -> FleetBuilder<'static> {
    Fleet::builder()
        .windows(windows)
        .rounds_per_window(20)
        .seed(23)
        .job_with_arrivals(
            paper_job(4).unwrap(), // mobv1-05: SM share climbs with instances
            PolicySpec::Static { bs: 1, mtl: 8 },
            ArrivalPattern::poisson(25.0),
        )
        .job_with_arrivals(
            paper_job(1).unwrap(), // inc-v1: high per-instance SM share
            PolicySpec::QueueAware,
            ArrivalPattern::trace((0..800).map(|i| i as f64 * 0.001).collect()).unwrap(),
        )
}

fn assert_outcomes_identical(a: &FleetOutcome, b: &FleetOutcome) {
    assert_eq!(a.contention_trace, b.contention_trace, "contention traces diverged");
    assert_eq!(a.total_throughput, b.total_throughput);
    assert_eq!(a.total_goodput, b.total_goodput);
    assert_eq!(a.peak_mem_mb, b.peak_mem_mb);
    assert_eq!(a.admission_clamps, b.admission_clamps);
    assert_eq!(a.members.len(), b.members.len());
    for (ma, mb) in a.members.iter().zip(&b.members) {
        assert_eq!(ma.throughput, mb.throughput, "{}: throughput", ma.dnn);
        assert_eq!(ma.p95_ms, mb.p95_ms, "{}: p95", ma.dnn);
        assert_eq!(ma.slo_attainment, mb.slo_attainment, "{}: attainment", ma.dnn);
        assert_eq!(ma.arrived, mb.arrived, "{}: arrived", ma.dnn);
        assert_eq!(ma.trace.len(), mb.trace.len());
        for (ra, rb) in ma.trace.iter().zip(&mb.trace) {
            assert_eq!(ra.p95_ms, rb.p95_ms, "{} w{}: window p95", ma.dnn, ra.window);
            assert_eq!(ra.throughput, rb.throughput, "{} w{}", ma.dnn, ra.window);
            assert_eq!((ra.bs, ra.mtl), (rb.bs, rb.mtl), "{} w{}", ma.dnn, ra.window);
        }
    }
}

#[test]
fn explicit_timeshare_is_byte_identical_to_the_default_fleet() {
    // `partition_mode(TimeShare)` must be the SAME serving computation
    // as a fleet that never mentions partitioning — same device-RNG
    // consumption, same window accounting, bit for bit. (The golden
    // fixtures in tests/golden.rs additionally pin these numbers across
    // future refactors.)
    let default_run = burst_fleet(24).build().unwrap().run().unwrap();
    let explicit = burst_fleet(24)
        .partition_mode(PartitionMode::TimeShare)
        .build()
        .unwrap()
        .run()
        .unwrap();
    assert_outcomes_identical(&default_run, &explicit);
    assert!(explicit.grant_trace.is_empty());
}

#[test]
fn full_grant_mps_matches_uncontended_timeshare_bitwise() {
    // A single-member MPS fleet holding the WHOLE device must reproduce
    // the uncontended TimeShare fleet exactly: grant 1.0 routes through
    // the granted perf model, whose g = 1 path is the whole-GPU model,
    // and the noise stream is consumed identically. Member chosen so its
    // solo SM utilization stays below 1 (TimeShare factor = 1.0).
    let solo = |b: FleetBuilder<'static>| {
        b.windows(10).rounds_per_window(8).seed(7).job_with_arrivals(
            paper_job(19).unwrap(), // mobv1-05 on Caltech: tiny SM footprint
            PolicySpec::Static { bs: 1, mtl: 1 },
            ArrivalPattern::poisson(30.0),
        )
    };
    let ts = solo(Fleet::builder()).build().unwrap().run().unwrap();
    assert!(
        ts.peak_contention < 1.0,
        "scenario must be uncontended for the comparison to be exact (got {})",
        ts.peak_contention
    );
    let mps = solo(Fleet::builder().partition_mode(PartitionMode::Mps))
        .sm_reservation(1.0)
        .build()
        .unwrap()
        .run()
        .unwrap();
    let a = &ts.members[0];
    let b = &mps.members[0];
    assert_eq!(a.throughput, b.throughput);
    assert_eq!(a.p95_ms, b.p95_ms);
    assert_eq!(a.slo_attainment, b.slo_attainment);
    for (ra, rb) in a.trace.iter().zip(&b.trace) {
        assert_eq!(ra.p95_ms, rb.p95_ms, "w{}", ra.window);
        assert_eq!(ra.mean_ms, rb.mean_ms, "w{}", ra.window);
        assert_eq!(ra.throughput, rb.throughput, "w{}", ra.window);
    }
}

// ---------------------------------------------------------------------------
// MPS interference isolation (the acceptance scenario)
// ---------------------------------------------------------------------------

/// Worst-window tail inflation of the steady member (index 0) in `loud`
/// relative to its twin in `quiet` — the cross-member interference
/// metric: same arrivals, same device noise, same operating point, only
/// the neighbour differs.
fn interference(loud: &FleetOutcome, quiet: &FleetOutcome) -> f64 {
    let worst = |l: &[WindowRecord], q: &[WindowRecord]| {
        l.iter()
            .zip(q)
            .filter(|(_, q)| q.p95_ms > 0.0)
            .map(|(l, q)| l.p95_ms / q.p95_ms)
            .fold(0.0f64, f64::max)
    };
    worst(&loud.members[0].trace, &quiet.members[0].trace)
}

/// Quiet twin of [`burst_fleet`]: the neighbour holds (1, 1) forever, so
/// whatever coupling the mode allows stays constant.
fn quiet_fleet(windows: usize, mode: PartitionMode) -> FleetBuilder<'static> {
    Fleet::builder()
        .windows(windows)
        .rounds_per_window(20)
        .seed(23)
        .partition_mode(mode)
        .job_with_arrivals(
            paper_job(4).unwrap(),
            PolicySpec::Static { bs: 1, mtl: 8 },
            ArrivalPattern::poisson(25.0),
        )
        .job_with_arrivals(
            paper_job(1).unwrap(),
            PolicySpec::Static { bs: 1, mtl: 1 },
            ArrivalPattern::trace((0..800).map(|i| i as f64 * 0.001).collect()).unwrap(),
        )
}

#[test]
fn mps_partition_shows_lower_cross_member_interference_than_timeshare() {
    let windows = 48;

    // TimeShare: the neighbour's burst-driven scale-up inflates the
    // steady member's tail through the shared contention factor.
    let ts_quiet = quiet_fleet(windows, PartitionMode::TimeShare).build().unwrap().run().unwrap();
    let ts_loud = burst_fleet(windows).build().unwrap().run().unwrap();
    let ts_interference = interference(&ts_loud, &ts_quiet);
    assert!(
        ts_interference > 1.05,
        "TimeShare burst must visibly degrade the steady member (got {ts_interference:.3}x)"
    );

    // MPS: same scenario, but each member holds half the SMs (no
    // explicit reservations -> equal split). The neighbour's scale-up
    // can only slow the neighbour itself, inside its own partition.
    let mps_quiet =
        quiet_fleet(windows, PartitionMode::Mps).build().unwrap().run().unwrap();
    let mps_loud = burst_fleet(windows)
        .partition_mode(PartitionMode::Mps)
        .build()
        .unwrap()
        .run()
        .unwrap();
    let mps_interference = interference(&mps_loud, &mps_quiet);

    assert!(
        mps_interference < ts_interference,
        "MPS must isolate the steady member better than time-sharing \
         ({mps_interference:.3}x vs {ts_interference:.3}x)"
    );
    assert!(
        mps_interference < 1.05,
        "a spatially isolated member's tail must not visibly degrade \
         (got {mps_interference:.3}x)"
    );
    // The spatial admission ledger never over-subscribes the SMs.
    for out in [&mps_quiet, &mps_loud] {
        assert!(out.contention_trace.iter().all(|&c| c <= 1.0 + 1e-9));
        assert!(!out.grant_trace.is_empty());
        for grants in &out.grant_trace {
            assert!((grants.iter().sum::<f64>() - 1.0).abs() < 1e-9, "equal split fills the GPU");
        }
    }
    // Quantified isolation bonus: the bursty member still made progress
    // inside its own partition in both fleets.
    assert!(mps_loud.members[1].arrived == 800 && ts_loud.members[1].arrived == 800);
}
