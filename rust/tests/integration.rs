//! Integration tests: cross-module behaviour of the full stack —
//! Profiler + Scaler + runner against the simulated P40, and (when
//! artifacts exist) the real PJRT runtime end to end.

use dnnscaler::coordinator::job::{paper_job, JobSpec, SteadyKnob, PAPER_JOBS};
use dnnscaler::coordinator::runner::{JobRunner, RunConfig};
use dnnscaler::coordinator::{Method, Profiler, ALPHA};
use dnnscaler::device::real::RealDevice;
use dnnscaler::device::Device;
use dnnscaler::gpusim::{Dataset, GpuSim};
use dnnscaler::manifest::Manifest;

fn artifacts_dir() -> std::path::PathBuf {
    std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}

// ---------------------------------------------------------------------------
// Simulated-device integration
// ---------------------------------------------------------------------------

#[test]
fn full_workload_dnnscaler_never_loses_badly_and_mostly_wins() {
    let runner = JobRunner::new(RunConfig::windows(30, 20));
    let mut wins = 0;
    for job in PAPER_JOBS {
        let mut d1 = GpuSim::for_paper_dnn(job.dnn, job.dataset, 100 + job.id as u64).unwrap();
        let s = runner.run_dnnscaler(job, &mut d1).unwrap();
        let mut d2 = GpuSim::for_paper_dnn(job.dnn, job.dataset, 200 + job.id as u64).unwrap();
        let c = runner.run_clipper(job, &mut d2).unwrap();
        let gain = s.throughput / c.throughput;
        // DNNScaler must never collapse vs Clipper (B-jobs tie ~1.0).
        assert!(gain > 0.6, "job {}: gain {gain:.2}", job.id);
        if gain > 1.1 {
            wins += 1;
        }
    }
    // The MT half of the workload must deliver real wins.
    assert!(wins >= 12, "only {wins} clear wins");
}

#[test]
fn dnnscaler_meets_slo_on_every_job_steady_state() {
    let runner = JobRunner::new(RunConfig::windows(30, 20));
    for job in PAPER_JOBS {
        let mut d = GpuSim::for_paper_dnn(job.dnn, job.dataset, 100 + job.id as u64).unwrap();
        let s = runner.run_dnnscaler(job, &mut d).unwrap();
        // Typical steady window within the SLO plus tail noise (spikes
        // and band-edge oscillation are explicitly tolerated by the
        // paper, §4.4 — so we bound the *median* steady window p95 and
        // overall attainment rather than the worst window).
        let steady = &s.trace[s.trace.len() / 2..];
        let mut p95s: Vec<f64> = steady.iter().map(|r| r.p95_ms).collect();
        p95s.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let median = p95s[p95s.len() / 2];
        assert!(
            median <= job.slo_ms * 1.25,
            "job {}: median steady p95 {:.1} vs SLO {}",
            job.id,
            median,
            job.slo_ms
        );
        // Steady-state attainment is the Fig. 6 claim: ~95% of requests
        // meet the SLO once the knob has converged. (Whole-run attainment
        // is dominated by the binary-search overshoot on short runs.)
        assert!(
            s.steady_attainment > 0.85,
            "job {}: steady attainment {}",
            job.id,
            s.steady_attainment
        );
    }
}

#[test]
fn mt_jobs_reach_paper_steady_mtl_within_two() {
    let runner = JobRunner::new(RunConfig::windows(40, 20));
    for job in PAPER_JOBS {
        if job.paper_method != Method::MultiTenancy {
            continue;
        }
        let mut d = GpuSim::for_paper_dnn(job.dnn, job.dataset, 100 + job.id as u64).unwrap();
        let s = runner.run_dnnscaler(job, &mut d).unwrap();
        if s.method != Some(Method::MultiTenancy) {
            continue; // method probes are noisy on borderline jobs
        }
        if let SteadyKnob::Mtl(paper) = job.paper_steady {
            let got = s.steady_mtl;
            assert!(
                (got as i64 - paper as i64).abs() <= 4,
                "job {}: steady MTL {got} vs paper {paper}",
                job.id
            );
        }
    }
}

#[test]
fn profiler_decision_is_stable_across_seeds() {
    // On the clear-cut jobs the method must not depend on the noise seed.
    let profiler = Profiler::default();
    for (dnn, ds, want) in [
        ("mobv1-025", Dataset::ImageNet, Method::MultiTenancy),
        ("inc-v4", Dataset::ImageNet, Method::Batching),
        ("nas-large", Dataset::ImageNet, Method::Batching),
        ("textclassif", Dataset::Sentiment140, Method::Batching),
    ] {
        for seed in 0..10u64 {
            let mut sim = GpuSim::for_paper_dnn(dnn, ds, seed).unwrap();
            let out = profiler.run(&mut sim).unwrap();
            assert_eq!(out.method, want, "{dnn} flipped at seed {seed}");
        }
    }
}

#[test]
fn launch_overhead_is_charged_for_mt_growth() {
    // A job that grows MTL must show depressed throughput in the window
    // right after a launch (the overhead is charged there).
    let job = paper_job(14).unwrap();
    let cfg = RunConfig::windows(20, 10);
    let mut d = GpuSim::for_paper_dnn(job.dnn, job.dataset, 77).unwrap();
    let overhead = d.launch_overhead_ms();
    assert!(overhead > 1000.0, "launching a TF instance costs seconds");
    let s = JobRunner::new(cfg).run_dnnscaler(job, &mut d).unwrap();
    assert!(s.throughput > 0.0);
}

#[test]
fn slo_schedule_batching_tracks_both_directions() {
    let job = JobSpec {
        id: 0,
        dnn: "inc-v4",
        dataset: Dataset::ImageNet,
        slo_ms: 400.0,
        paper_method: Method::Batching,
        paper_steady: SteadyKnob::Bs(1),
    };
    let cfg = RunConfig {
        windows: 60,
        rounds_per_window: 20,
        slo_schedule: vec![(20, 150.0), (40, 400.0)],
        ..Default::default()
    };
    let mut sim = GpuSim::for_paper_dnn("inc-v4", Dataset::ImageNet, 5).unwrap();
    let out = JobRunner::new(cfg).run_dnnscaler(&job, &mut sim).unwrap();
    let bs_at = |w: usize| out.trace[w].bs;
    assert!(bs_at(19) > bs_at(38), "tightened SLO must shrink BS");
    assert!(bs_at(59) > bs_at(38), "relaxed SLO must regrow BS");
    // Every phase ends SLO-compliant.
    for w in [19usize, 38, 59] {
        let r = &out.trace[w];
        assert!(r.p95_ms <= r.slo_ms * 1.2, "w{w}: p95 {:.1} slo {}", r.p95_ms, r.slo_ms);
    }
}

#[test]
fn alpha_band_prevents_thrashing() {
    // Once settled, the batch scaler must hold while p95 stays in
    // [alpha*SLO, SLO] — count knob changes over a long steady run.
    let job = paper_job(3).unwrap();
    let cfg = RunConfig::windows(60, 20);
    let mut d = GpuSim::for_paper_dnn(job.dnn, job.dataset, 9).unwrap();
    let s = JobRunner::new(cfg).run_dnnscaler(job, &mut d).unwrap();
    let steady = &s.trace[30..];
    let changes = steady.windows(2).filter(|w| w[0].bs != w[1].bs).count();
    assert!(changes <= steady.len() / 3, "knob thrashing: {changes} changes in steady state");
    assert!(ALPHA > 0.5 && ALPHA < 1.0);
}

// ---------------------------------------------------------------------------
// Real PJRT runtime integration (skipped when artifacts are absent)
// ---------------------------------------------------------------------------

#[test]
fn real_stack_serves_all_manifest_models() {
    let dir = artifacts_dir();
    if !dir.join("manifest.json").exists() {
        eprintln!("skipping: run `make artifacts`");
        return;
    }
    let manifest = Manifest::load(&dir).unwrap();
    manifest.validate().unwrap();
    for model in manifest.models() {
        let mut dev = RealDevice::open(&dir, &model).unwrap();
        let s = dev.execute_batch(1, 1).unwrap();
        assert!(s.latency_ms > 0.0, "{model}: zero latency");
        let s2 = dev.execute_batch(2, 1).unwrap();
        assert!(s2.latency_ms > 0.0);
    }
}

#[test]
fn real_stack_full_dnnscaler_loop() {
    let dir = artifacts_dir();
    if !dir.join("manifest.json").exists() {
        return;
    }
    let mut dev = RealDevice::open(&dir, "mobv1-025").unwrap();
    let max_bs = dev.max_batch_size();
    let job = JobSpec {
        id: 0,
        dnn: "mobv1-025",
        dataset: Dataset::Synthetic,
        slo_ms: 100.0,
        paper_method: Method::Batching,
        paper_steady: SteadyKnob::Bs(1),
    };
    let cfg = RunConfig {
        windows: 8,
        rounds_per_window: 6,
        max_bs,
        max_mtl: 3,
        probe_bs: max_bs,
        probe_mtl: 2,
        ..Default::default()
    };
    let out = JobRunner::new(cfg).run_dnnscaler(&job, &mut dev).unwrap();
    assert!(out.throughput > 0.0);
    assert!(out.p95_ms > 0.0);
    assert!(out.profile.is_some());
    // With a 100 ms SLO and sub-ms batches the scaler should use large
    // batches (relative to the exported max).
    assert!(out.steady_bs >= max_bs / 2 || out.steady_mtl > 1);
}

#[test]
fn real_logits_are_nonzero_and_deterministic() {
    // Regression test for the constant-eliding HLO-text bug: weights must
    // survive the python -> text -> rust round trip (aot.py prints with
    // print_large_constants=True).
    let dir = artifacts_dir();
    if !dir.join("manifest.json").exists() {
        return;
    }
    let manifest = Manifest::load(&dir).unwrap();
    let engine = dnnscaler::runtime::Engine::cpu().unwrap();
    for model in ["mobv1-025", "textcnn"] {
        let entry = manifest.get(model, 1).unwrap();
        let loaded = engine.load(&manifest, entry).unwrap();
        let input = vec![0.25f32; entry.input_elems()];
        let out = loaded.execute(&input).unwrap();
        assert!(
            out.iter().any(|v| v.abs() > 1e-6),
            "{model}: all-zero logits — weights lost in HLO text"
        );
        assert_eq!(out, loaded.execute(&input).unwrap());
    }
}
