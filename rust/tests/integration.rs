//! Integration tests: cross-module behaviour of the full stack —
//! Profiler + Policy + `ServingSession`/`Fleet` against the simulated
//! P40, and (when artifacts exist) the real PJRT runtime end to end.

use dnnscaler::coordinator::job::{paper_job, JobSpec, SteadyKnob, PAPER_JOBS};
use dnnscaler::coordinator::session::{JobOutcome, PolicySpec, RunConfig, ServingSession};
use dnnscaler::coordinator::{Fleet, Method, Profiler, ALPHA};
#[cfg(feature = "xla")]
use dnnscaler::device::real::RealDevice;
use dnnscaler::device::Device;
use dnnscaler::gpusim::{Dataset, GpuSim};
#[cfg(feature = "xla")]
use dnnscaler::manifest::Manifest;
use dnnscaler::workload::ArrivalPattern;

#[cfg(feature = "xla")]
fn artifacts_dir() -> std::path::PathBuf {
    std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}

/// Closed-loop session on a fresh simulator (the paper's serving mode).
fn run_closed(job: &JobSpec, cfg: RunConfig, seed: u64, spec: PolicySpec<'static>) -> JobOutcome {
    let sim = GpuSim::for_paper_dnn(job.dnn, job.dataset, seed).unwrap();
    ServingSession::builder()
        .config(cfg)
        .job(job)
        .device(sim)
        .policy(spec)
        .build()
        .unwrap()
        .run()
        .unwrap()
}

// ---------------------------------------------------------------------------
// Simulated-device integration (closed loop)
// ---------------------------------------------------------------------------

#[test]
fn full_workload_dnnscaler_never_loses_badly_and_mostly_wins() {
    let mut wins = 0;
    for job in PAPER_JOBS {
        let cfg = RunConfig::windows(30, 20);
        let s = run_closed(job, cfg.clone(), 100 + job.id as u64, PolicySpec::DnnScaler);
        let c = run_closed(job, cfg, 200 + job.id as u64, PolicySpec::Clipper);
        let gain = s.throughput / c.throughput;
        // DNNScaler must never collapse vs Clipper (B-jobs tie ~1.0).
        assert!(gain > 0.6, "job {}: gain {gain:.2}", job.id);
        if gain > 1.1 {
            wins += 1;
        }
    }
    // The MT half of the workload must deliver real wins.
    assert!(wins >= 12, "only {wins} clear wins");
}

#[test]
fn dnnscaler_meets_slo_on_every_job_steady_state() {
    for job in PAPER_JOBS {
        let s =
            run_closed(job, RunConfig::windows(30, 20), 100 + job.id as u64, PolicySpec::DnnScaler);
        // Typical steady window within the SLO plus tail noise (spikes
        // and band-edge oscillation are explicitly tolerated by the
        // paper, §4.4 — so we bound the *median* steady window p95 and
        // overall attainment rather than the worst window).
        let steady = &s.trace[s.trace.len() / 2..];
        let mut p95s: Vec<f64> = steady.iter().map(|r| r.p95_ms).collect();
        p95s.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let median = p95s[p95s.len() / 2];
        assert!(
            median <= job.slo_ms * 1.25,
            "job {}: median steady p95 {:.1} vs SLO {}",
            job.id,
            median,
            job.slo_ms
        );
        // Steady-state attainment is the Fig. 6 claim: ~95% of requests
        // meet the SLO once the knob has converged. (Whole-run attainment
        // is dominated by the binary-search overshoot on short runs.)
        assert!(
            s.steady_attainment > 0.85,
            "job {}: steady attainment {}",
            job.id,
            s.steady_attainment
        );
    }
}

#[test]
fn mt_jobs_reach_paper_steady_mtl_within_two() {
    for job in PAPER_JOBS {
        if job.paper_method != Method::MultiTenancy {
            continue;
        }
        let s =
            run_closed(job, RunConfig::windows(40, 20), 100 + job.id as u64, PolicySpec::DnnScaler);
        if s.method != Some(Method::MultiTenancy) {
            continue; // method probes are noisy on borderline jobs
        }
        if let SteadyKnob::Mtl(paper) = job.paper_steady {
            let got = s.steady_mtl;
            assert!(
                (got as i64 - paper as i64).abs() <= 4,
                "job {}: steady MTL {got} vs paper {paper}",
                job.id
            );
        }
    }
}

#[test]
fn profiler_decision_is_stable_across_seeds() {
    // On the clear-cut jobs the method must not depend on the noise seed.
    let profiler = Profiler::default();
    for (dnn, ds, want) in [
        ("mobv1-025", Dataset::ImageNet, Method::MultiTenancy),
        ("inc-v4", Dataset::ImageNet, Method::Batching),
        ("nas-large", Dataset::ImageNet, Method::Batching),
        ("textclassif", Dataset::Sentiment140, Method::Batching),
    ] {
        for seed in 0..10u64 {
            let mut sim = GpuSim::for_paper_dnn(dnn, ds, seed).unwrap();
            let out = profiler.run(&mut sim).unwrap();
            assert_eq!(out.method, want, "{dnn} flipped at seed {seed}");
        }
    }
}

#[test]
fn launch_overhead_is_charged_for_mt_growth() {
    // A job that grows MTL must show depressed throughput in the window
    // right after a launch (the overhead is charged there).
    let job = paper_job(14).unwrap();
    let d = GpuSim::for_paper_dnn(job.dnn, job.dataset, 77).unwrap();
    let overhead = d.launch_overhead_ms();
    assert!(overhead > 1000.0, "launching a TF instance costs seconds");
    let s = ServingSession::builder()
        .config(RunConfig::windows(20, 10))
        .job(job)
        .device(d)
        .policy(PolicySpec::DnnScaler)
        .build()
        .unwrap()
        .run()
        .unwrap();
    assert!(s.throughput > 0.0);
}

#[test]
fn slo_schedule_batching_tracks_both_directions() {
    let job = JobSpec {
        id: 0,
        dnn: "inc-v4",
        dataset: Dataset::ImageNet,
        slo_ms: 400.0,
        paper_method: Method::Batching,
        paper_steady: SteadyKnob::Bs(1),
    };
    let cfg = RunConfig {
        windows: 60,
        rounds_per_window: 20,
        slo_schedule: vec![(20, 150.0), (40, 400.0)],
        ..Default::default()
    };
    let out = run_closed(&job, cfg, 5, PolicySpec::DnnScaler);
    let bs_at = |w: usize| out.trace[w].bs;
    assert!(bs_at(19) > bs_at(38), "tightened SLO must shrink BS");
    assert!(bs_at(59) > bs_at(38), "relaxed SLO must regrow BS");
    // Every phase ends SLO-compliant.
    for w in [19usize, 38, 59] {
        let r = &out.trace[w];
        assert!(r.p95_ms <= r.slo_ms * 1.2, "w{w}: p95 {:.1} slo {}", r.p95_ms, r.slo_ms);
    }
}

#[test]
fn alpha_band_prevents_thrashing() {
    // Once settled, the batch scaler must hold while p95 stays in
    // [alpha*SLO, SLO] — count knob changes over a long steady run.
    let job = paper_job(3).unwrap();
    let s = run_closed(job, RunConfig::windows(60, 20), 9, PolicySpec::DnnScaler);
    let steady = &s.trace[30..];
    let changes = steady.windows(2).filter(|w| w[0].bs != w[1].bs).count();
    assert!(changes <= steady.len() / 3, "knob thrashing: {changes} changes in steady state");
    assert!(ALPHA > 0.5 && ALPHA < 1.0);
}

// ---------------------------------------------------------------------------
// Open-loop serving (the event-driven core)
// ---------------------------------------------------------------------------

#[test]
fn open_loop_burst_shows_queueing_delay_and_reconverges() {
    // Job 1 (inc-v1, SLO 35 ms) under bursty open-loop load: 30 req/s
    // base with 2x bursts (1 s of every 4 s). Closed-loop DNNScaler rides
    // at MTL >= 6-8 where the service latency alone (~33 ms) fills the
    // SLO; open loop adds batch-formation wait and queueing, so the MT
    // scaler must re-converge to a lower instance count with headroom —
    // and still keep steady attainment high. (Parameters chosen so the
    // scaler settles 3-5 instances with attainment ~0.92-0.96 across
    // seeds; 40 rounds/window keeps the per-window p95 rank deep enough
    // that single OS-jitter spikes do not thrash the knob.)
    let job = paper_job(1).unwrap();
    let sim = GpuSim::for_paper_dnn(job.dnn, job.dataset, 17).unwrap();
    let out = ServingSession::builder()
        .config(RunConfig::windows(40, 40))
        .job(job)
        .device(sim)
        .policy(PolicySpec::DnnScaler)
        .arrivals(ArrivalPattern::bursty(30.0, 2.0, 4.0, 1.0))
        .batch_timeout_ms(3.0)
        .seed(17)
        .build()
        .unwrap()
        .run()
        .unwrap();
    assert_eq!(out.method, Some(Method::MultiTenancy));
    // Queueing delay is visible in the observed p95: it must exceed the
    // noise-free service latency at the steady operating point (by well
    // over the ~1.1x the latency noise alone could explain).
    let twin = GpuSim::for_paper_dnn(job.dnn, job.dataset, 17).unwrap();
    let service = twin.mean_batch_latency_ms(out.steady_bs.max(1), out.steady_mtl.max(1));
    assert!(
        out.p95_ms > service * 1.2,
        "p95 sojourn {:.2} must exceed service latency {:.2}",
        out.p95_ms,
        service
    );
    // Re-convergence: below the closed-loop knee, above collapse.
    assert!(
        (2..8).contains(&out.steady_mtl),
        "steady mtl {} (expected re-convergence below the closed-loop 8)",
        out.steady_mtl
    );
    // §3.3's claim under burst: attainment recovers once re-converged.
    assert!(
        out.steady_attainment >= 0.9,
        "steady attainment {:.3} must recover to >= 90%",
        out.steady_attainment
    );
    // The queue actually built up during bursts, and nothing was dropped
    // (the queue is unbounded here).
    assert!(out.queue_peak >= 2, "queue peak {}", out.queue_peak);
    assert_eq!(out.drops, 0);
    assert!(out.trace.iter().any(|r| r.queue_peak > 1));
    // Arrival-rate telemetry is populated in open loop.
    assert!(out.trace.iter().any(|r| r.arrival_rate > 10.0));
}

#[test]
fn open_loop_throughput_is_arrival_bound_not_capacity_bound() {
    // At light load the server must serve what arrives, not spin at
    // device capacity the way the closed loop does.
    let job = paper_job(1).unwrap();
    let cfg = RunConfig::windows(20, 20);
    let closed = run_closed(job, cfg.clone(), 31, PolicySpec::DnnScaler);
    let sim = GpuSim::for_paper_dnn(job.dnn, job.dataset, 31).unwrap();
    let open = ServingSession::builder()
        .config(cfg)
        .job(job)
        .device(sim)
        .policy(PolicySpec::DnnScaler)
        .arrivals(ArrivalPattern::poisson(30.0))
        .seed(31)
        .build()
        .unwrap()
        .run()
        .unwrap();
    assert!(
        open.throughput < closed.throughput * 0.7,
        "open {:.1} vs closed {:.1}: open loop must be offered-load bound",
        open.throughput,
        closed.throughput
    );
    // ... and roughly track the offered 30 req/s.
    assert!(open.throughput > 10.0 && open.throughput < 60.0, "thr {:.1}", open.throughput);
}

#[test]
fn fleet_serves_multiple_jobs_on_shared_gpu_without_oom() {
    // Three DNNs co-located on one 24 GB P40: an MT-heavy job, a
    // batching job, and a mobilenet. The fleet must finish without OOM,
    // keep combined memory under capacity, and actually contend for SMs.
    let out = Fleet::builder()
        .windows(20)
        .rounds_per_window(10)
        .seed(5)
        .job(paper_job(1).unwrap(), PolicySpec::DnnScaler)
        .job(paper_job(3).unwrap(), PolicySpec::DnnScaler)
        .job(paper_job(4).unwrap(), PolicySpec::DnnScaler)
        .build()
        .unwrap()
        .run()
        .unwrap();
    assert_eq!(out.members.len(), 3);
    for m in &out.members {
        assert!(m.throughput > 0.0, "{}: zero throughput", m.dnn);
        assert!((0.0..=1.0).contains(&m.slo_attainment), "{}: attainment", m.dnn);
        assert_eq!(m.trace.len(), 20);
    }
    assert!(out.peak_mem_mb > 0.0);
    assert!(
        out.peak_mem_mb <= out.mem_capacity_mb,
        "admission control must keep {} MB under {} MB",
        out.peak_mem_mb,
        out.mem_capacity_mb
    );
    assert!(
        out.peak_contention > 1.0,
        "contention {:.2}: jobs never shared SMs",
        out.peak_contention
    );
    assert!(out.total_throughput > 0.0);
}

// ---------------------------------------------------------------------------
// Real PJRT runtime integration (needs the `xla` feature; skipped when
// artifacts are absent)
// ---------------------------------------------------------------------------

#[cfg(feature = "xla")]
#[test]
fn real_stack_serves_all_manifest_models() {
    let dir = artifacts_dir();
    if !dir.join("manifest.json").exists() {
        eprintln!("skipping: run `make artifacts`");
        return;
    }
    let manifest = Manifest::load(&dir).unwrap();
    manifest.validate().unwrap();
    for model in manifest.models() {
        let mut dev = RealDevice::open(&dir, &model).unwrap();
        let s = dev.execute_batch(1, 1).unwrap();
        assert!(s.latency_ms > 0.0, "{model}: zero latency");
        let s2 = dev.execute_batch(2, 1).unwrap();
        assert!(s2.latency_ms > 0.0);
    }
}

#[cfg(feature = "xla")]
#[test]
fn real_stack_full_dnnscaler_loop() {
    let dir = artifacts_dir();
    if !dir.join("manifest.json").exists() {
        return;
    }
    let mut dev = RealDevice::open(&dir, "mobv1-025").unwrap();
    let max_bs = dev.max_batch_size();
    let job = JobSpec {
        id: 0,
        dnn: "mobv1-025",
        dataset: Dataset::Synthetic,
        slo_ms: 100.0,
        paper_method: Method::Batching,
        paper_steady: SteadyKnob::Bs(1),
    };
    let cfg = RunConfig {
        windows: 8,
        rounds_per_window: 6,
        max_bs,
        max_mtl: 3,
        probe_bs: max_bs,
        probe_mtl: 2,
        ..Default::default()
    };
    let out = ServingSession::builder()
        .config(cfg)
        .job(&job)
        .device(&mut dev)
        .policy(PolicySpec::DnnScaler)
        .build()
        .unwrap()
        .run()
        .unwrap();
    assert!(out.throughput > 0.0);
    assert!(out.p95_ms > 0.0);
    assert!(out.profile.is_some());
    // With a 100 ms SLO and sub-ms batches the scaler should use large
    // batches (relative to the exported max).
    assert!(out.steady_bs >= max_bs / 2 || out.steady_mtl > 1);
}

#[cfg(feature = "xla")]
#[test]
fn real_logits_are_nonzero_and_deterministic() {
    // Regression test for the constant-eliding HLO-text bug: weights must
    // survive the python -> text -> rust round trip (aot.py prints with
    // print_large_constants=True).
    let dir = artifacts_dir();
    if !dir.join("manifest.json").exists() {
        return;
    }
    let manifest = Manifest::load(&dir).unwrap();
    let engine = dnnscaler::runtime::Engine::cpu().unwrap();
    for model in ["mobv1-025", "textcnn"] {
        let entry = manifest.get(model, 1).unwrap();
        let loaded = engine.load(&manifest, entry).unwrap();
        let input = vec![0.25f32; entry.input_elems()];
        let out = loaded.execute(&input).unwrap();
        assert!(
            out.iter().any(|v| v.abs() > 1e-6),
            "{model}: all-zero logits — weights lost in HLO text"
        );
        assert_eq!(out, loaded.execute(&input).unwrap());
    }
}
