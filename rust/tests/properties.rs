//! Property-based tests over the coordinator invariants (hand-rolled
//! generator loop — this build is offline, so no proptest crate; the
//! shrink-free seeded-case pattern below covers the same ground).
//!
//! Each property runs a few hundred randomized cases derived from a
//! deterministic RNG, so failures are reproducible from the printed seed.

use dnnscaler::coordinator::clipper::Clipper;
use dnnscaler::coordinator::latency::LatencyWindow;
use dnnscaler::coordinator::matcomp::{pick_mtl, LatencyLibrary};
use dnnscaler::coordinator::scaler_batching::BatchScaler;
use dnnscaler::coordinator::scaler_mt::MtScaler;
use dnnscaler::coordinator::{Controller, MAX_BS, MAX_MTL};
use dnnscaler::gpusim::{perf, Dataset, DnnProfile};
use dnnscaler::json;
use dnnscaler::linalg::{svd, Mat};
use dnnscaler::metrics::WeightedCdf;
use dnnscaler::rng::Rng;
use dnnscaler::workload::{ArrivalGenerator, ArrivalPattern, RequestQueue};

/// Run `cases` seeded property cases.
fn forall(cases: u64, mut body: impl FnMut(u64, &mut Rng)) {
    for seed in 0..cases {
        let mut rng = Rng::new(0xC0FFEE ^ seed.wrapping_mul(0x9E3779B97F4A7C15));
        body(seed, &mut rng);
    }
}

/// Random-but-physical DNN profile.
fn random_profile(rng: &mut Rng) -> DnnProfile {
    let mut p = dnnscaler::gpusim::paper_profile("inc-v1").unwrap();
    p.weight_mb = rng.uniform_range(1.0, 400.0);
    p.t_fl_ms = rng.uniform_range(0.01, 5.0);
    p.bsat = rng.uniform_range(1.0, 40.0);
    p.r1 = rng.uniform_range(0.05, 1.0);
    p.t_gpu_fixed_ms = rng.uniform_range(0.1, 3.0);
    p.t_prep_ms = rng.uniform_range(0.05, 50.0);
    p.prep_growth = rng.uniform_range(0.0, 0.01);
    p.kappa = rng.uniform_range(0.0, 0.5);
    p
}

// ---------------------------------------------------------------------------
// Batch scaler properties
// ---------------------------------------------------------------------------

#[test]
fn prop_batch_scaler_stays_in_bounds_under_adversarial_p95() {
    forall(300, |seed, rng| {
        let mut s = BatchScaler::new();
        for _ in 0..100 {
            let p95 = if rng.chance(0.5) { rng.uniform_range(0.0, 1e5) } else { f64::INFINITY };
            let d = s.observe_window(p95, rng.uniform_range(1.0, 1e4));
            assert!((1..=MAX_BS).contains(&d.bs), "seed {seed}: bs {}", d.bs);
            assert_eq!(d.mtl, 1);
        }
    });
}

#[test]
fn prop_batch_scaler_converges_to_feasible_knee() {
    // For any monotone latency curve lat(b) = a*b + c with a feasible
    // region, the scaler must settle at an SLO-compliant batch size that
    // is at least alpha-efficient (within the hysteresis band of the
    // knee) in O(log MAX_BS) moves.
    forall(200, |seed, rng| {
        let a = rng.uniform_range(0.05, 5.0);
        let c = rng.uniform_range(0.0, 10.0);
        let slo = rng.uniform_range(c + a * 1.5, c + a * 200.0);
        let lat = |b: u32| a * b as f64 + c;
        let mut s = BatchScaler::new();
        let mut moves = 0;
        for _ in 0..40 {
            let bs = s.batch_size();
            if s.observe_window(lat(bs), slo).changed {
                moves += 1;
            }
        }
        let bs = s.batch_size();
        assert!(lat(bs) <= slo * 1.0001, "seed {seed}: settled on violation (bs={bs})");
        // Either the knee is reached (next step violates / at cap) or we
        // are inside the alpha band.
        let next_violates = bs == MAX_BS || lat(bs + (bs).max(1)) > slo;
        let in_band = lat(bs) >= 0.85 * slo * 0.5; // loose efficiency floor
        assert!(next_violates || in_band, "seed {seed}: bs {bs} left too much headroom");
        assert!(moves <= 2 * 7 + 6, "seed {seed}: {moves} moves for a 7-bit search");
    });
}

#[test]
fn prop_batch_scaler_tracks_any_slo_change() {
    forall(100, |seed, rng| {
        let a = rng.uniform_range(0.1, 3.0);
        let lat = |b: u32| a * b as f64;
        let slo1 = rng.uniform_range(a * 2.0, a * 128.0);
        let slo2 = rng.uniform_range(a * 2.0, a * 128.0);
        let mut s = BatchScaler::new();
        for _ in 0..30 {
            let bs = s.batch_size();
            s.observe_window(lat(bs), slo1);
        }
        for _ in 0..30 {
            let bs = s.batch_size();
            s.observe_window(lat(bs), slo2);
        }
        let bs = s.batch_size();
        // Within one knob step of compliance: when no batch size lands in
        // the [alpha*SLO, SLO] band (knob quantization coarser than the
        // band) the controller legitimately oscillates bs* <-> bs*+1.
        assert!(
            lat(bs) <= slo2 || lat(bs.saturating_sub(1).max(1)) <= slo2,
            "seed {seed}: p95 {} > SLO2 {} beyond one step",
            lat(bs),
            slo2
        );
    });
}

// ---------------------------------------------------------------------------
// MT scaler / AIMD properties
// ---------------------------------------------------------------------------

#[test]
fn prop_mt_scaler_bounds_and_aimd_feasibility() {
    forall(200, |seed, rng| {
        let base = rng.uniform_range(1.0, 50.0);
        let slope = rng.uniform_range(0.0, 1.0);
        let lat = |n: u32| base * (1.0 + slope * (n - 1) as f64);
        let slo = rng.uniform_range(base * 1.01, base * 12.0);
        let mut s = MtScaler::unseeded(rng.below(10) as u32 + 1, MAX_MTL);
        for _ in 0..30 {
            let n = s.mtl();
            let d = s.observe_window(lat(n), slo);
            assert!((1..=MAX_MTL).contains(&d.mtl), "seed {seed}");
        }
        let n = s.mtl();
        // Feasible within one AIMD step: when the feasible knee sits
        // below the alpha band the controller legitimately oscillates
        // n* <-> n*+1 (the paper's Algorithm 1 does the same).
        assert!(
            lat(n) <= slo || n == 1 || lat(n - 1) <= slo,
            "seed {seed}: mtl {n} more than one step above feasibility"
        );
        // Efficient: adding one more would violate, or at the cap, or in
        // the alpha band.
        let maxed = n == MAX_MTL || lat(n + 1) > slo || lat(n) >= 0.85 * slo;
        assert!(maxed, "seed {seed}: mtl {n} leaves headroom (lat {} slo {slo})", lat(n));
    });
}

#[test]
fn prop_matcomp_estimates_physical() {
    // For any target curve drawn from the same family as the library,
    // completion must return positive, monotone estimates that pin the
    // observations exactly.
    forall(100, |seed, rng| {
        let lib_rows: Vec<Vec<f64>> = (0..6)
            .map(|_| {
                let k = rng.uniform_range(0.02, 0.9);
                (0..10).map(|j| 1.0 + k * j as f64).collect()
            })
            .collect();
        let lib = LatencyLibrary::from_rows(lib_rows);
        let base = rng.uniform_range(1.0, 100.0);
        let k = rng.uniform_range(0.02, 0.9);
        let truth: Vec<f64> = (0..10).map(|j| base * (1.0 + k * j as f64)).collect();
        let est = lib.complete(&[(1, truth[0]), (8, truth[7])]);
        assert_eq!(est.len(), 10);
        assert_eq!(est[0], truth[0], "seed {seed}");
        assert_eq!(est[7], truth[7], "seed {seed}");
        for w in est.windows(2) {
            assert!(w[1] >= w[0] - 1e-9, "seed {seed}: non-monotone {est:?}");
        }
        assert!(est.iter().all(|&v| v >= 0.0), "seed {seed}");
        // pick_mtl consistency: the chosen MTL's estimate meets the SLO.
        let slo = rng.uniform_range(base, base * 12.0);
        let n = pick_mtl(&est, slo);
        assert!((1..=10).contains(&n));
        if est[0] <= slo {
            assert!(est[n as usize - 1] <= slo, "seed {seed}");
        }
    });
}

// ---------------------------------------------------------------------------
// Clipper properties
// ---------------------------------------------------------------------------

#[test]
fn prop_clipper_never_exceeds_bounds_and_backs_off() {
    forall(150, |seed, rng| {
        let knee = rng.below(100) as u32 + 2;
        let lat = move |b: u32| if b > knee { 1e6 } else { 1.0 };
        let mut c = Clipper::new();
        let mut last_violation_bs = None;
        for _ in 0..80 {
            let b = c.batch_size();
            let p95 = lat(b);
            let before = c.batch_size();
            c.observe_window(p95, 100.0);
            assert!((1..=MAX_BS).contains(&c.batch_size()), "seed {seed}");
            if p95 > 100.0 {
                assert!(c.batch_size() < before.max(2), "seed {seed}: no back-off");
                last_violation_bs = Some(before);
            }
        }
        if let Some(v) = last_violation_bs {
            assert!(v > knee, "seed {seed}: violated below the knee");
        }
    });
}

// ---------------------------------------------------------------------------
// Simulator surface properties (random profiles, not just paper ones)
// ---------------------------------------------------------------------------

#[test]
fn prop_perf_surface_monotone_and_positive() {
    forall(200, |seed, rng| {
        let p = random_profile(rng);
        let ds = Dataset::ImageNet;
        let mut prev = 0.0;
        for b in [1u32, 2, 4, 8, 16, 32, 64, 128] {
            let t = perf::batch_latency_ms(&p, ds, b, 1).total_ms;
            assert!(t > prev, "seed {seed}: latency not monotone in bs");
            prev = t;
        }
        let mut prev = 0.0;
        for n in 1..=10u32 {
            let t = perf::batch_latency_ms(&p, ds, 1, n).total_ms;
            assert!(t >= prev, "seed {seed}: latency not monotone in mtl");
            prev = t;
            let u = perf::sm_utilization(&p, ds, 1, n);
            assert!((0.0..=1.0).contains(&u), "seed {seed}: util {u}");
        }
    });
}

#[test]
fn prop_throughput_bounded_by_serial_rate() {
    // Throughput at any (b, n) can never exceed n * b / gpu-fixed time —
    // a crude physical ceiling.
    forall(200, |seed, rng| {
        let p = random_profile(rng);
        let b = rng.below(128) as u32 + 1;
        let n = rng.below(10) as u32 + 1;
        let thr = perf::throughput(&p, Dataset::ImageNet, b, n);
        let ceiling = (n as f64) * (b as f64) / (p.t_gpu_fixed_ms / 1000.0);
        assert!(thr > 0.0 && thr <= ceiling, "seed {seed}: thr {thr} ceiling {ceiling}");
    });
}

// ---------------------------------------------------------------------------
// Metrics / substrate properties
// ---------------------------------------------------------------------------

#[test]
fn prop_latency_window_percentile_matches_naive() {
    forall(200, |seed, rng| {
        let n = rng.below(50) + 1;
        let mut w = LatencyWindow::new(n);
        let mut all = Vec::new();
        for _ in 0..n {
            let v = rng.uniform_range(0.0, 1e3);
            w.record(v);
            all.push(v);
        }
        all.sort_by(|a, b| a.partial_cmp(b).unwrap());
        for q in [0.05, 0.5, 0.95, 1.0] {
            let rank = ((q * n as f64).ceil() as usize).clamp(1, n);
            assert_eq!(w.percentile(q), Some(all[rank - 1]), "seed {seed} q {q} n {n}");
        }
    });
}

#[test]
fn prop_weighted_cdf_quantile_matches_expansion() {
    forall(100, |seed, rng| {
        let mut cdf = WeightedCdf::new();
        let mut expanded = Vec::new();
        for _ in 0..rng.below(30) + 1 {
            let v = rng.uniform_range(0.0, 100.0);
            let w = rng.below(5) + 1;
            cdf.add(v, w as f64);
            for _ in 0..w {
                expanded.push(v);
            }
        }
        expanded.sort_by(|a, b| a.partial_cmp(b).unwrap());
        for q in [0.25, 0.5, 0.95] {
            let want = expanded[((q * expanded.len() as f64).ceil() as usize)
                .clamp(1, expanded.len())
                - 1];
            let got = cdf.quantile(q).unwrap();
            assert!((got - want).abs() < 1e-9, "seed {seed}: q {q} got {got} want {want}");
        }
    });
}

#[test]
fn prop_svd_reconstructs_random_matrices() {
    forall(60, |seed, rng| {
        let m = rng.below(8) + 1;
        let n = rng.below(8) + 1;
        let data: Vec<f64> = (0..m * n).map(|_| rng.uniform_range(-5.0, 5.0)).collect();
        let a = Mat::from_rows(m, n, &data);
        let d = svd(&a);
        let r = d.reconstruct(0);
        let err = a.sub(&r).fro_norm();
        assert!(err < 1e-7 * a.fro_norm().max(1.0), "seed {seed}: err {err}");
        for w in d.s.windows(2) {
            assert!(w[0] >= w[1] - 1e-12, "seed {seed}: s not sorted");
        }
    });
}

#[test]
fn prop_json_roundtrip_random_values() {
    fn random_json(rng: &mut Rng, depth: usize) -> json::Json {
        match if depth == 0 { rng.below(4) } else { rng.below(6) } {
            0 => json::Json::Null,
            1 => json::Json::Bool(rng.chance(0.5)),
            2 => json::Json::Num((rng.uniform_range(-1e6, 1e6) * 100.0).round() / 100.0),
            3 => {
                let n = rng.below(12);
                json::Json::Str((0..n).map(|_| (b'a' + rng.below(26) as u8) as char).collect())
            }
            4 => json::Json::Arr((0..rng.below(4)).map(|_| random_json(rng, depth - 1)).collect()),
            _ => json::Json::Obj(
                (0..rng.below(4))
                    .map(|i| (format!("k{i}"), random_json(rng, depth - 1)))
                    .collect(),
            ),
        }
    }
    forall(300, |seed, rng| {
        let v = random_json(rng, 3);
        let text = json::write(&v);
        let back = json::parse(&text).unwrap_or_else(|e| panic!("seed {seed}: {e} in {text}"));
        assert_eq!(back, v, "seed {seed}");
    });
}

#[test]
fn prop_queue_fifo_matches_model() {
    forall(150, |seed, rng| {
        let mut q = RequestQueue::new();
        let mut model: Vec<f64> = Vec::new();
        let mut clock = 0.0;
        for _ in 0..60 {
            if rng.chance(0.6) {
                clock += rng.uniform_range(0.001, 0.1);
                assert!(q.push(clock).is_some(), "seed {seed}: unbounded push");
                model.push(clock);
            } else {
                let k = rng.below(4) + 1;
                let got = q.take_batch(k);
                let want: Vec<f64> = model.drain(..k.min(model.len())).collect();
                assert_eq!(got.len(), want.len(), "seed {seed}");
                for (g, w) in got.iter().zip(&want) {
                    assert_eq!(g.arrival_s, *w, "seed {seed}");
                }
            }
            assert_eq!(q.len(), model.len(), "seed {seed}");
        }
        assert_eq!(q.dropped, 0, "seed {seed}: unbounded queue dropped");
    });
}

#[test]
fn prop_bounded_queue_fifo_and_drop_accounting() {
    // Under random push/drain traffic against a random capacity, the
    // bounded queue must (a) preserve FIFO order of *accepted* requests,
    // (b) drop exactly the arrivals that found it full, and (c) never
    // exceed its capacity.
    forall(150, |seed, rng| {
        let cap = rng.below(6) + 1;
        let mut q = RequestQueue::bounded(cap);
        let mut model: Vec<f64> = Vec::new();
        let mut expected_drops = 0u64;
        let mut clock = 0.0;
        for _ in 0..80 {
            if rng.chance(0.7) {
                clock += rng.uniform_range(0.001, 0.1);
                if model.len() < cap {
                    assert!(q.push(clock).is_some(), "seed {seed}: push below cap");
                    model.push(clock);
                } else {
                    assert!(q.push(clock).is_none(), "seed {seed}: push at cap");
                    expected_drops += 1;
                }
            } else {
                let k = rng.below(4) + 1;
                let got = q.take_batch(k);
                let want: Vec<f64> = model.drain(..k.min(model.len())).collect();
                assert_eq!(got.len(), want.len(), "seed {seed}");
                for (g, w) in got.iter().zip(&want) {
                    assert_eq!(g.arrival_s, *w, "seed {seed}: FIFO broken");
                }
            }
            assert!(q.len() <= cap, "seed {seed}: len {} over cap {cap}", q.len());
            assert_eq!(q.len(), model.len(), "seed {seed}");
            assert_eq!(q.dropped, expected_drops, "seed {seed}");
        }
        assert!(q.max_depth <= cap, "seed {seed}");
    });
}

#[test]
fn prop_ring_queue_is_a_vecdeque_under_arbitrary_interleavings() {
    // The PR 4 ring-buffer rewrite of RequestQueue must be behaviorally
    // indistinguishable from the straightforward VecDeque implementation
    // it replaced, under arbitrary interleavings of push / take_batch /
    // shed_expired — contents, FIFO order, ids, and every counter
    // (drops, deadline sheds, depth high-water mark) included. Small
    // capacities keep the ring wrapping and regrowing constantly.
    use std::collections::VecDeque;
    forall(250, |seed, rng| {
        let cap = if rng.chance(0.5) { Some(rng.below(10) + 1) } else { None };
        let mut q = cap.map_or_else(RequestQueue::new, RequestQueue::bounded);
        let mut model: VecDeque<(u64, f64)> = VecDeque::new();
        let mut next_id = 0u64;
        let mut dropped = 0u64;
        let mut shed_total = 0u64;
        let mut max_depth = 0usize;
        let mut clock = 0.0f64;
        let mut scratch = Vec::new();
        for _ in 0..250 {
            match rng.below(4) {
                // Weighted toward arrivals so depth actually builds.
                0 | 1 => {
                    clock += rng.uniform_range(0.0, 0.05);
                    let got = q.push(clock);
                    if cap.is_some_and(|c| model.len() >= c) {
                        assert!(got.is_none(), "seed {seed}: push at cap must drop");
                        dropped += 1;
                    } else {
                        assert_eq!(got, Some(next_id), "seed {seed}: id sequence");
                        model.push_back((next_id, clock));
                        next_id += 1;
                        max_depth = max_depth.max(model.len());
                    }
                }
                2 => {
                    let k = rng.below(6);
                    q.take_batch_into(k, &mut scratch);
                    assert_eq!(scratch.len(), k.min(model.len()), "seed {seed}");
                    for r in &scratch {
                        let (id, t) = model.pop_front().expect("model underflow");
                        assert_eq!((r.id, r.arrival_s), (id, t), "seed {seed}: FIFO broken");
                    }
                }
                _ => {
                    let deadline_ms = rng.uniform_range(0.0, 60.0);
                    let now = clock + rng.uniform_range(0.0, 0.03);
                    let shed = q.shed_expired(now, deadline_ms);
                    let mut want = 0u64;
                    while model
                        .front()
                        .is_some_and(|&(_, t)| (now - t) * 1000.0 > deadline_ms)
                    {
                        model.pop_front();
                        want += 1;
                    }
                    assert_eq!(shed, want, "seed {seed}: shed count");
                    shed_total += shed;
                }
            }
            assert_eq!(q.len(), model.len(), "seed {seed}");
            assert_eq!(q.is_empty(), model.is_empty(), "seed {seed}");
            assert_eq!(q.oldest_arrival(), model.front().map(|&(_, t)| t), "seed {seed}");
            assert_eq!(q.dropped, dropped, "seed {seed}");
            assert_eq!(q.dropped_deadline, shed_total, "seed {seed}");
            assert_eq!(q.max_depth, max_depth, "seed {seed}");
        }
    });
}

#[test]
fn prop_poisson_rate_concentrates() {
    forall(20, |seed, rng| {
        let rate = rng.uniform_range(50.0, 2000.0);
        let mut g = ArrivalGenerator::new(ArrivalPattern::Poisson { rate }, seed);
        let n = g.arrivals_until(10.0).len() as f64;
        let got = n / 10.0;
        assert!(
            (got - rate).abs() / rate < 0.15,
            "seed {seed}: rate {got:.1} want {rate:.1}"
        );
    });
}

#[test]
fn prop_poisson_interarrival_mean_is_inverse_rate() {
    // The defining property of the exponential gap sampler: the mean
    // inter-arrival time concentrates on 1/rate.
    forall(25, |seed, rng| {
        let rate = rng.uniform_range(20.0, 800.0);
        let mut g = ArrivalGenerator::new(ArrivalPattern::poisson(rate), 0xA11CE ^ seed);
        let a = g.arrivals_until(40.0);
        assert!(a.len() > 100, "seed {seed}: too few arrivals ({})", a.len());
        let mut gaps = Vec::with_capacity(a.len() - 1);
        for w in a.windows(2) {
            let gap = w[1] - w[0];
            assert!(gap > 0.0, "seed {seed}: non-positive gap");
            gaps.push(gap);
        }
        let mean = gaps.iter().sum::<f64>() / gaps.len() as f64;
        let want = 1.0 / rate;
        assert!(
            (mean - want).abs() / want < 0.15,
            "seed {seed}: mean gap {mean:.5} want {want:.5}"
        );
    });
}

#[test]
fn prop_bursty_pattern_alternates_between_rates() {
    // Arrivals inside the burst phase must be denser than outside, by
    // roughly the burst factor (we assert at least half of it to leave
    // room for sampling noise).
    forall(20, |seed, rng| {
        let rate = rng.uniform_range(50.0, 300.0);
        let factor = rng.uniform_range(3.0, 8.0);
        let (period, burst) = (2.0, 0.5);
        let pattern = ArrivalPattern::bursty(rate, factor, period, burst);
        let mut g = ArrivalGenerator::new(pattern, 0xB00 ^ seed);
        let a = g.arrivals_until(40.0);
        let in_burst = a.iter().filter(|t| *t % period < burst).count() as f64;
        let off_burst = a.iter().filter(|t| *t % period >= burst).count() as f64;
        assert!(off_burst > 0.0, "seed {seed}");
        // Empirical per-second rates in each phase.
        let burst_rate = in_burst / (40.0 * burst / period);
        let base_rate = off_burst / (40.0 * (period - burst) / period);
        let ratio = burst_rate / base_rate;
        assert!(
            ratio > factor / 2.0 && ratio < factor * 2.0,
            "seed {seed}: burst/base rate ratio {ratio:.2} vs factor {factor:.2}"
        );
        // rate_at reports the alternation exactly.
        assert_eq!(g.rate_at(0.1), rate * factor, "seed {seed}");
        assert_eq!(g.rate_at(1.0), rate, "seed {seed}");
    });
}
