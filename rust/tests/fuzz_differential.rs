//! Whole-cluster differential fuzzing (ISSUE 8 tentpole): ≥ 200 seeded
//! random scenarios spanning open/closed arrivals × {TimeShare, MPS,
//! MIG} × {static, churn + migration + autoscaling}, each served by the
//! production engine AND by `testkit`'s deliberately naive reference
//! executor (O(M) min-scan instead of the calendar, fresh accumulators
//! instead of recycled ones, device-outer loops, no threads). Both
//! outcomes must render byte-identical snapshots and pass
//! `ClusterOutcome::audit()` — always, not just under `debug_assert!`.
//!
//! The oracle's teeth are proven by `Mutation`: an injected fast-side
//! bug must be caught and shrunk to a counterexample with at most two
//! devices and two jobs.

use dnnscaler::coordinator::testkit::{
    check_scenario, describe_failure, fallback_scenario, from_canon, generate_class, run_fuzz,
    shrink, to_canon, Mutation, NUM_CLASSES,
};

/// The acceptance-criteria soak: 204 scenarios, 34 per class, zero
/// mismatches, every class represented.
#[test]
fn fuzz_differential_200_scenarios_match_and_audit_clean() {
    let cases = 204;
    let report = run_fuzz(cases, 0xD1FF_5EED, None);
    assert_eq!(report.cases, cases);
    if let Some(f) = report.failures.first() {
        panic!(
            "{} of {} scenarios mismatched; first:\n{}",
            report.failures.len(),
            cases,
            describe_failure(f)
        );
    }
    for (class, &built) in report.built.iter().enumerate() {
        assert!(
            built >= cases / NUM_CLASSES,
            "class {class} produced {built} buildable scenarios (want {})",
            cases / NUM_CLASSES
        );
    }
}

/// An injected engine bug (inflated headline throughput) is caught on
/// every affected case and shrinks to ≤ 2 devices and ≤ 2 jobs.
#[test]
fn injected_bug_is_caught_and_shrunk_to_a_tiny_counterexample() {
    let report = run_fuzz(NUM_CLASSES * 2, 77, Some(Mutation::InflateTotalThroughput));
    assert!(
        !report.failures.is_empty(),
        "the mutation hook must trip the differential oracle"
    );
    for f in &report.failures {
        assert!(
            f.shrunk.device_count() <= 2,
            "case {} shrunk to {} devices:\n{}",
            f.case,
            f.shrunk.device_count(),
            describe_failure(f)
        );
        assert!(
            f.shrunk.job_count() <= 2,
            "case {} shrunk to {} jobs:\n{}",
            f.case,
            f.shrunk.job_count(),
            describe_failure(f)
        );
        assert!(!f.mismatch.is_empty());
    }
}

/// A conservation violation (more drops than arrivals) is refused by the
/// always-run `audit()`, which `debug_assert!` alone would skip in
/// release builds.
#[test]
fn forged_drops_are_refused_by_the_always_run_audit() {
    for class in 0..NUM_CLASSES {
        let sc = fallback_scenario(class, 9);
        let err = check_scenario(&sc, Some(Mutation::ForgePhantomDrops))
            .expect_err("forged drops must fail");
        assert!(
            err.contains("audit"),
            "class {class}: expected an audit failure, got: {err}"
        );
    }
}

/// Generated scenarios round-trip exactly through the canonical corpus
/// format, for every class.
#[test]
fn generated_scenarios_round_trip_through_canonical_format() {
    for class in 0..NUM_CLASSES {
        for seed in [1u64, 42, 0xABCD] {
            let sc = generate_class(class, seed);
            let text = to_canon(&sc);
            let back = from_canon(&text)
                .unwrap_or_else(|e| panic!("class {class} seed {seed}: {e}\n{text}"));
            assert_eq!(back, sc, "class {class} seed {seed} round-trip drift:\n{text}");
        }
    }
}

/// The campaign is a pure function of (cases, seed): same failures, same
/// class coverage, byte-identical shrunk counterexamples.
#[test]
fn fuzz_campaign_is_deterministic() {
    let a = run_fuzz(36, 0xFEED, Some(Mutation::InflateTotalThroughput));
    let b = run_fuzz(36, 0xFEED, Some(Mutation::InflateTotalThroughput));
    assert_eq!(a.built, b.built);
    assert_eq!(a.failures.len(), b.failures.len());
    for (fa, fb) in a.failures.iter().zip(&b.failures) {
        assert_eq!(fa.case, fb.case);
        assert_eq!(to_canon(&fa.shrunk), to_canon(&fb.shrunk));
    }
}

/// `shrink` never returns a passing scenario: the minimized output still
/// fails the same predicate it was shrunk against.
#[test]
fn shrink_preserves_failure() {
    let sc = generate_class(5, 0x5EED);
    let mutation = Some(Mutation::InflateTotalThroughput);
    let mut failing = |c: &dnnscaler::coordinator::testkit::Scenario| {
        check_scenario(c, mutation).is_err()
    };
    if !failing(&sc) {
        // A scenario whose run errs out never reaches the mutation; the
        // campaign-level test covers those. Nothing to shrink here.
        return;
    }
    let small = shrink(&sc, &mut failing);
    assert!(failing(&small), "shrunk scenario must still fail:\n{}", to_canon(&small));
}
