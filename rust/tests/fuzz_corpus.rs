//! Replays the committed regression corpus under `tests/fuzz_corpus/`:
//! every `.case` file is a canonical-format scenario (shrunk fuzzer
//! counterexamples and hand-picked coverage cases) that must build,
//! serve identically through the fast and reference executors, and
//! audit clean — as ordinary tier-1 tests, no fuzzing involved.
//!
//! `REGEN_FUZZ_CORPUS=1` (driven by `make fuzz-corpus`) rewrites each
//! file to its canonical serialization instead of asserting it; the
//! Makefile target then fails on git drift, exactly like the golden
//! fixtures' regenerator.

use dnnscaler::coordinator::testkit::{check_scenario, from_canon, to_canon};
use std::fs;
use std::path::PathBuf;

fn corpus_dir() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("tests/fuzz_corpus")
}

#[test]
fn corpus_cases_replay_clean_and_stay_canonical() {
    let dir = corpus_dir();
    let mut paths: Vec<PathBuf> = fs::read_dir(&dir)
        .unwrap_or_else(|e| panic!("{}: {e}", dir.display()))
        .map(|e| e.unwrap().path())
        .filter(|p| p.extension().is_some_and(|x| x == "case"))
        .collect();
    paths.sort();
    assert!(paths.len() >= 11, "corpus shrank to {} cases", paths.len());

    let regen = std::env::var_os("REGEN_FUZZ_CORPUS").is_some_and(|v| v == "1");
    for p in &paths {
        let name = p.file_name().unwrap().to_string_lossy().into_owned();
        let text = fs::read_to_string(p).unwrap();
        let sc = from_canon(&text).unwrap_or_else(|e| panic!("{name}: {e}"));
        let canon = to_canon(&sc);
        if regen {
            fs::write(p, &canon).unwrap();
        } else {
            assert_eq!(
                canon, text,
                "{name} is not in canonical form; run `make fuzz-corpus` to re-bless"
            );
        }
        // A corpus case that stops building would silently stop testing
        // anything — refuse vacuous entries.
        assert!(sc.builds(), "{name} no longer passes builder validation");
        if let Err(e) = check_scenario(&sc, None) {
            panic!("{name}: fast and reference executors disagree:\n{e}");
        }
    }
}
