//! Fault-injection integration tests: crashes, degradation, repair, and
//! retry-with-backoff failover through the public `Cluster` API —
//! including the failover-vs-stranded goodput A/B, the audit with
//! crash-lost work, thread-count byte-identity on faulty runs, and the
//! fault-free byte-identity guarantee.

use dnnscaler::coordinator::cluster::ClusterOutcome;
use dnnscaler::coordinator::dynamics::ChurnSchedule;
use dnnscaler::coordinator::job::paper_job;
use dnnscaler::coordinator::session::{ConfigError, PolicySpec};
use dnnscaler::coordinator::snapshot::{cluster_outcome_to_json, render};
use dnnscaler::coordinator::{Cluster, FaultSchedule};
use dnnscaler::gpusim::TESLA_P40;
use dnnscaler::workload::ArrivalPattern;

fn snapshot(out: &ClusterOutcome) -> String {
    render(&cluster_outcome_to_json(out))
}

/// Out-of-range targets, double crashes, repairs of healthy devices,
/// and nonsense degrade factors are all typed `ConfigError::BadFaults`
/// from `build()` — never runtime surprises.
#[test]
fn invalid_fault_schedules_fail_at_build() {
    let base = || {
        Cluster::builder()
            .device(TESLA_P40)
            .device(TESLA_P40)
            .job_with_arrivals(
                paper_job(1).unwrap(),
                PolicySpec::Static { bs: 1, mtl: 1 },
                ArrivalPattern::poisson(20.0),
            )
            .windows(6)
            .rounds_per_window(8)
            .seed(3)
    };
    let cases: Vec<(&str, FaultSchedule)> = vec![
        ("device out of range", FaultSchedule::new().crash(7, 1)),
        ("window out of range", FaultSchedule::new().crash(0, 6)),
        ("double crash", FaultSchedule::new().crash(0, 1).crash(0, 3)),
        ("repair of never-crashed device", FaultSchedule::new().repair(0, 2)),
        (
            "repair of already-repaired device",
            FaultSchedule::new().crash(0, 1).repair(0, 2).repair(0, 3),
        ),
        ("degrade of a down device", FaultSchedule::new().crash(0, 1).degrade(0, 2, 0.5, 2)),
        ("degrade factor zero", FaultSchedule::new().degrade(0, 1, 0.0, 2)),
        ("degrade factor above one", FaultSchedule::new().degrade(0, 1, 1.5, 2)),
        ("degrade for zero windows", FaultSchedule::new().degrade(0, 1, 0.5, 0)),
    ];
    for (what, sched) in cases {
        let err = base().faults(sched).build().err().unwrap_or_else(|| {
            panic!("{what} must be rejected at build");
        });
        assert!(matches!(err, ConfigError::BadFaults { .. }), "{what}: got {err:?}");
    }
    // Bad stochastic parameters are equally typed.
    for (mtbf, mttr) in [(0.0, 1.0), (-3.0, 1.0), (3.0, 0.0), (f64::NAN, 1.0), (3.0, f64::NAN)] {
        let err = base()
            .stochastic_faults(mtbf, mttr)
            .build()
            .err()
            .unwrap_or_else(|| panic!("mtbf {mtbf} / mttr {mttr} must be rejected"));
        assert!(matches!(err, ConfigError::BadFaults { .. }), "got {err:?}");
    }
}

/// Crashing the only device at window 0 strands the job for the whole
/// run: nothing serves, nothing fails over, and the accounting still
/// balances (no phantom arrivals, no phantom drops).
#[test]
fn crash_at_window_zero_of_the_only_device_strands_the_job() {
    let out = Cluster::builder()
        .device(TESLA_P40)
        .job_with_arrivals(
            paper_job(1).unwrap(),
            PolicySpec::Static { bs: 1, mtl: 1 },
            ArrivalPattern::poisson(25.0),
        )
        .faults(FaultSchedule::new().crash(0, 0))
        .windows(4)
        .rounds_per_window(8)
        .seed(5)
        .build()
        .unwrap()
        .run()
        .unwrap();
    let dy = out.dynamics.as_ref().expect("faulty run must report dynamics");
    let fo = dy.faults.as_ref().expect("faulty run must report fault telemetry");
    assert_eq!(fo.crashes, 1);
    assert_eq!(fo.failovers, 0, "there is nowhere to fail over to");
    assert_eq!(fo.deferred_jobs, 1);
    assert_eq!(fo.pool_health, vec![0; 4], "the only device is down all run");
    assert_eq!(out.total_throughput, 0.0);
    assert_eq!(out.audit(), Ok(()));
}

/// Crash the only device, then repair it: the stranded job's backoff
/// retry re-places it on the repaired card and it finishes the run.
#[test]
fn stranded_job_returns_after_repair() {
    let out = Cluster::builder()
        .device(TESLA_P40)
        .job_with_arrivals(
            paper_job(1).unwrap(),
            PolicySpec::Static { bs: 1, mtl: 1 },
            ArrivalPattern::poisson(25.0),
        )
        .faults(FaultSchedule::new().crash(0, 1).repair(0, 2))
        .windows(8)
        .rounds_per_window(8)
        .seed(7)
        .build()
        .unwrap()
        .run()
        .unwrap();
    let dy = out.dynamics.as_ref().unwrap();
    let fo = dy.faults.as_ref().unwrap();
    assert_eq!(fo.crashes, 1);
    assert_eq!(fo.repairs, 1);
    assert_eq!(fo.deferred_jobs, 1, "the crash must park the job");
    assert_eq!(fo.failovers, 1, "the retry must re-place it after the repair");
    assert!(fo.failover_stall_ms > 0.0, "re-placement pays the model load");
    assert_eq!(fo.pool_health, vec![1, 0, 1, 1, 1, 1, 1, 1]);
    let served: usize = out.devices.iter().map(|d| d.fleet.members.len()).sum();
    assert_eq!(served, 1, "the job must finish with a real outcome");
    assert!(out.total_throughput > 0.0);
    assert_eq!(out.audit(), Ok(()));
}

/// A crash while a heavily-loaded job holds a backlog drops that queue
/// into `dropped_failure`; the conservation audit must account for it
/// and the snapshot must expose it.
#[test]
fn crash_drops_queued_work_and_the_audit_accounts_for_it() {
    // Job 3 (inc-v4) at 150 req/s oversubscribes a P40: a backlog is
    // guaranteed to be standing in the queue at every window boundary.
    let out = Cluster::builder()
        .device(TESLA_P40)
        .device(TESLA_P40)
        .job_with_arrivals(
            paper_job(3).unwrap(),
            PolicySpec::Static { bs: 1, mtl: 1 },
            ArrivalPattern::poisson(150.0),
        )
        .job_with_arrivals(
            paper_job(1).unwrap(),
            PolicySpec::Static { bs: 1, mtl: 1 },
            ArrivalPattern::poisson(20.0),
        )
        .faults(FaultSchedule::new().crash(0, 2))
        .windows(6)
        .rounds_per_window(10)
        .seed(11)
        .build()
        .unwrap()
        .run()
        .unwrap();
    let dy = out.dynamics.as_ref().unwrap();
    let fo = dy.faults.as_ref().unwrap();
    assert_eq!(fo.crashes, 1);
    assert_eq!(fo.failovers, 1, "the survivor has room for the evacuee");
    assert!(fo.dropped_failure > 0, "the standing backlog must be lost to the crash");
    let member_losses: u64 = out
        .devices
        .iter()
        .flat_map(|d| d.fleet.members.iter())
        .map(|m| m.dropped_failure)
        .sum();
    assert_eq!(member_losses, fo.dropped_failure, "per-job and pool telemetry must agree");
    assert_eq!(out.audit(), Ok(()), "conservation must hold with crash losses counted");
    let snap = snapshot(&out);
    assert!(snap.contains("\"dropped_failure\""));
    assert!(snap.contains("\"faults\""));
}

/// The e2e acceptance pin: a 4-device pool serving 4 jobs loses one
/// device mid-run. With failover the evacuated job keeps serving
/// elsewhere; with failover disabled it is stranded. Failover must
/// strictly win on total goodput, and both runs must audit clean.
#[test]
fn failover_strictly_beats_stranding_on_goodput() {
    let run = |failover: bool| {
        let sched = FaultSchedule::new().crash(1, 3).failover(failover);
        Cluster::builder()
            .device(TESLA_P40)
            .device(TESLA_P40)
            .device(TESLA_P40)
            .device(TESLA_P40)
            .job_with_arrivals(
                paper_job(1).unwrap(),
                PolicySpec::Static { bs: 2, mtl: 1 },
                ArrivalPattern::poisson(30.0),
            )
            .job_with_arrivals(
                paper_job(4).unwrap(),
                PolicySpec::Static { bs: 1, mtl: 1 },
                ArrivalPattern::poisson(30.0),
            )
            .job_with_arrivals(
                paper_job(5).unwrap(),
                PolicySpec::Static { bs: 1, mtl: 1 },
                ArrivalPattern::poisson(25.0),
            )
            .job_with_arrivals(
                paper_job(10).unwrap(),
                PolicySpec::Static { bs: 1, mtl: 1 },
                ArrivalPattern::poisson(25.0),
            )
            .faults(sched)
            .windows(10)
            .rounds_per_window(12)
            .seed(13)
            .build()
            .unwrap()
            .run()
            .unwrap()
    };
    let with_failover = run(true);
    let stranded = run(false);

    let fo = with_failover.dynamics.as_ref().unwrap().faults.as_ref().unwrap();
    assert_eq!(fo.crashes, 1);
    assert_eq!(fo.failovers, 1, "the dead device's job must be re-placed");
    let fo_off = stranded.dynamics.as_ref().unwrap().faults.as_ref().unwrap();
    assert_eq!(fo_off.crashes, 1);
    assert_eq!(fo_off.failovers, 0, "failover disabled must strand the job");
    assert_eq!(fo_off.deferred_jobs, 1);

    assert!(
        with_failover.total_goodput > stranded.total_goodput,
        "failover must strictly beat stranding: {} vs {} inf/s",
        with_failover.total_goodput,
        stranded.total_goodput
    );
    assert_eq!(with_failover.audit(), Ok(()));
    assert_eq!(stranded.audit(), Ok(()));
}

/// Degradation throttles a device's SM grant for exactly its configured
/// duration; the job keeps serving throughout (no drops to failure) and
/// the run stays deterministic.
#[test]
fn degrade_is_temporary_and_deterministic() {
    let run = || {
        Cluster::builder()
            .device(TESLA_P40)
            .device(TESLA_P40)
            .job_with_arrivals(
                paper_job(1).unwrap(),
                PolicySpec::Static { bs: 2, mtl: 1 },
                ArrivalPattern::poisson(40.0),
            )
            .job_with_arrivals(
                paper_job(5).unwrap(),
                PolicySpec::Static { bs: 1, mtl: 1 },
                ArrivalPattern::poisson(30.0),
            )
            .faults(FaultSchedule::new().degrade(0, 2, 0.4, 3))
            .windows(8)
            .rounds_per_window(10)
            .seed(17)
            .build()
            .unwrap()
            .run()
            .unwrap()
    };
    let a = run();
    let b = run();
    assert_eq!(snapshot(&a), snapshot(&b), "degraded runs must be deterministic");
    let fo = a.dynamics.as_ref().unwrap().faults.as_ref().unwrap();
    assert_eq!(fo.degrades, 1);
    assert_eq!(fo.crashes, 0);
    assert_eq!(fo.dropped_failure, 0, "degradation slows serving, it loses nothing");
    assert_eq!(fo.pool_health, vec![2; 8], "a degraded device is still healthy");
    assert_eq!(a.audit(), Ok(()));
}

/// Fault decisions happen serially at the window barrier, so a faulty
/// run is byte-identical at every worker-thread count.
#[test]
fn faulty_runs_are_byte_identical_across_thread_counts() {
    let run = |threads: usize| {
        Cluster::builder()
            .device(TESLA_P40)
            .device(TESLA_P40)
            .device(TESLA_P40)
            .job_with_arrivals(
                paper_job(1).unwrap(),
                PolicySpec::Static { bs: 2, mtl: 1 },
                ArrivalPattern::poisson(35.0),
            )
            .job_with_arrivals(
                paper_job(4).unwrap(),
                PolicySpec::Static { bs: 1, mtl: 1 },
                ArrivalPattern::poisson(25.0),
            )
            .job_with_arrivals(
                paper_job(5).unwrap(),
                PolicySpec::Static { bs: 1, mtl: 1 },
                ArrivalPattern::poisson(25.0),
            )
            .faults(
                FaultSchedule::new().crash(2, 1).degrade(0, 2, 0.5, 2).repair(2, 4),
            )
            .windows(8)
            .rounds_per_window(10)
            .seed(19)
            .threads(threads)
            .build()
            .unwrap()
            .run()
            .unwrap()
    };
    let serial = snapshot(&run(1));
    for threads in [2usize, 8] {
        assert_eq!(
            serial,
            snapshot(&run(threads)),
            "faulty run must be byte-identical at {threads} threads"
        );
    }
}

/// Property over 100 seeds: stochastic MTBF/MTTR fault processes always
/// produce valid schedules, clean audits, and full-length health traces
/// — and the same seed always materializes the same fault history.
#[test]
fn stochastic_fault_runs_audit_clean_across_seeds() {
    for seed in 0..100u64 {
        let run = || {
            Cluster::builder()
                .device(TESLA_P40)
                .device(TESLA_P40)
                .device(TESLA_P40)
                .job_with_arrivals(
                    paper_job(1).unwrap(),
                    PolicySpec::Static { bs: 1, mtl: 1 },
                    ArrivalPattern::poisson(25.0),
                )
                .job_with_arrivals(
                    paper_job(5).unwrap(),
                    PolicySpec::Static { bs: 1, mtl: 1 },
                    ArrivalPattern::poisson(20.0),
                )
                .stochastic_faults(3.0, 2.0)
                .windows(8)
                .rounds_per_window(6)
                .seed(seed)
                .build()
                .unwrap()
                .run()
                .unwrap()
        };
        let out = run();
        let dy = out.dynamics.as_ref().expect("stochastic mode is a dynamic run");
        let fo = dy.faults.as_ref().expect("stochastic mode must report fault telemetry");
        assert_eq!(fo.pool_health.len(), 8, "seed {seed}");
        assert!(fo.pool_health.iter().all(|&h| h <= 3), "seed {seed}");
        assert!(fo.repairs <= fo.crashes, "seed {seed}: repairs cannot outnumber crashes");
        assert_eq!(out.audit(), Ok(()), "seed {seed}");
        if seed % 25 == 0 {
            assert_eq!(snapshot(&out), snapshot(&run()), "seed {seed}: must be reproducible");
        }
    }
}

/// The byte-identity contract: a run with no fault events — even with
/// an explicitly attached empty schedule — must not flip onto the fault
/// path, and its snapshot must contain none of the fault-era keys.
#[test]
fn fault_free_runs_carry_no_fault_keys_and_empty_schedules_are_inert() {
    let run = |decorate: bool| {
        let churn = ChurnSchedule::new().launch(
            2,
            paper_job(4).unwrap(),
            PolicySpec::Static { bs: 1, mtl: 1 },
            ArrivalPattern::poisson(20.0),
        );
        let mut b = Cluster::builder()
            .device(TESLA_P40)
            .device(TESLA_P40)
            .job_with_arrivals(
                paper_job(1).unwrap(),
                PolicySpec::Static { bs: 2, mtl: 1 },
                ArrivalPattern::poisson(30.0),
            )
            .churn(churn)
            .windows(6)
            .rounds_per_window(10)
            .seed(23);
        if decorate {
            b = b.faults(FaultSchedule::new());
        }
        b.build().unwrap().run().unwrap()
    };
    let plain = run(false);
    let decorated = run(true);
    assert!(plain.dynamics.as_ref().unwrap().faults.is_none());
    assert!(
        decorated.dynamics.as_ref().unwrap().faults.is_none(),
        "an empty schedule must not enable the fault path"
    );
    let snap = snapshot(&plain);
    assert_eq!(snap, snapshot(&decorated), "empty schedules must be byte-inert");
    assert!(!snap.contains("\"faults\""));
    assert!(!snap.contains("\"dropped_failure\""));
    assert!(!snap.contains("\"deferred_launches\""));

    // A fully static run (no dynamics at all) is equally clean.
    let static_out = Cluster::builder()
        .device(TESLA_P40)
        .job(paper_job(1).unwrap(), PolicySpec::Static { bs: 2, mtl: 1 })
        .windows(4)
        .rounds_per_window(8)
        .seed(29)
        .build()
        .unwrap()
        .run()
        .unwrap();
    assert!(static_out.dynamics.is_none());
    let snap = snapshot(&static_out);
    assert!(!snap.contains("\"faults\""));
    assert!(!snap.contains("\"dropped_failure\""));
}
